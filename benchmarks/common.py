"""Shared helpers for the paper-reproduction benchmarks.

Every benchmark emits CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the wall-clock cost of producing the cell (the simulator
call) and ``derived`` is the metric the paper's figure plots (transfer
seconds, utilization %, ...).  Extra context columns follow ``derived``.
"""

from __future__ import annotations

import os
import sys
import time

import numpy as np

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in (os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, os.path.abspath(_SRC))

from repro.core import (  # noqa: E402
    Aria2Policy,
    BitTorrentPolicy,
    MDTPPolicy,
    StaticChunkingPolicy,
    simulate,
)

GB = 1024**3

POLICIES = {
    "mdtp": MDTPPolicy,
    "static": StaticChunkingPolicy,
    "aria2": Aria2Policy,
    "bittorrent": BitTorrentPolicy,
}

#: every emit() lands here too, so drivers can serialize a whole run
#: (``benchmarks.run --json`` → BENCH_autotune.json).
_ROWS: list[dict] = []


def emit(name: str, us_per_call: float, derived, *extra) -> None:
    cols = [name, f"{us_per_call:.1f}", str(derived)] + [str(e) for e in extra]
    _ROWS.append({
        "name": name,
        "us_per_call": float(us_per_call),
        "derived": str(derived),
        "extra": [str(e) for e in extra],
    })
    print(",".join(cols), flush=True)


def emitted_rows() -> list[dict]:
    """All rows emitted so far in this process (insertion order)."""
    return list(_ROWS)


def reset_rows() -> None:
    """Drop accumulated rows (drivers call this at run start so a second
    in-process run can't leak stale rows into its --json artifact)."""
    _ROWS.clear()


def run_cells(name, policy_name, servers, file_size, reps: int, policy_kwargs=None):
    """Average ``reps`` seeded simulations; returns (mean_s, stderr_s)."""
    times = []
    t0 = time.perf_counter()
    for seed in range(reps):
        pol = POLICIES[policy_name](**(policy_kwargs or {}))
        res = simulate(pol, servers, file_size, seed=seed)
        res.check_integrity()
        times.append(res.total_time)
    wall_us = (time.perf_counter() - t0) * 1e6 / max(reps, 1)
    mean = float(np.mean(times))
    stderr = float(np.std(times) / np.sqrt(len(times))) if len(times) > 1 else 0.0
    emit(name, wall_us, f"{mean:.2f}", f"stderr={stderr:.3f}")
    return mean, stderr
