"""Paper Fig. 4: throttle the fastest server to 500 Mbps (32/64 GB).

Paper: MDTP degrades by +42 s (32 GB) / +48 s (64 GB); Aria2 by +74 s /
+121 s — Aria2 suffers more because it leaves slow-replica capacity unused.
Static chunking "was unable to adapt ... excessively long transfer times"
and was excluded; we include it anyway for completeness.
"""

from __future__ import annotations

import argparse

from .common import GB, emit, run_cells
from repro.core.scenarios import paper_baseline, with_throttled_fastest


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[32, 64])
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--limit-mbps", type=float, default=500.0)
    args = ap.parse_args(argv)

    base = paper_baseline()
    thr = with_throttled_fastest(
        base, limit_bytes_per_s=args.limit_mbps * 1e6 / 8
    )
    for gb in args.sizes:
        deltas = {}
        for proto in ("mdtp", "aria2", "static"):
            t0, _ = run_cells(f"fig4/base/{proto}/{gb}GB", proto, base,
                              gb * GB, args.reps)
            t1, _ = run_cells(f"fig4/throttled/{proto}/{gb}GB", proto, thr,
                              gb * GB, args.reps)
            deltas[proto] = t1 - t0
            emit(f"fig4/delta/{proto}/{gb}GB", 0.0, f"{t1 - t0:+.2f}")
        emit(
            f"fig4/aria2_vs_mdtp_delta_ratio/{gb}GB", 0.0,
            f"{deltas['aria2'] / max(deltas['mdtp'], 1e-9):.2f}",
        )


if __name__ == "__main__":
    main()
