"""Flash-crowd economics: the overload-robust manager vs the plain one.

The robustness layer's overload claim is that admission control (SRPT
queue + max-active gate), endgame hedging, and replica probation jointly
cut the TAIL of per-transfer makespans when a storm of arrivals meets a
fleet whose fastest mirror silently degrades.  This bench measures that
claim on real loopback sockets, replaying the storm *shape* of
``repro.core.scenarios.flash_crowd_traces`` at CI scale:

``flashcrowd/burst/{plain,robust}``
    A flash crowd: N equal transfers arrive within ~0.5 s on a clean
    three-mirror fleet.  ``plain`` is the PR-6-style manager
    (``hedge_quantile=0, probation=False``, no admission); ``robust``
    is the current defaults plus ``max_active_transfers`` and an
    in-flight byte budget.

``flashcrowd/gray/{plain,robust}``
    The same storm while the FASTEST mirror silently degrades to 10% of
    its bandwidth mid-storm (``RangeServer.set_throttle`` — the
    real-socket mirror of ``ServerSpec.degrade_at``).  The compound
    case hedging + probation + admission are jointly built for.

``flashcrowd/gray/waste``
    Hedging's cost on the gray storm: duplicated (losing-copy) bytes as
    a percentage of delivered bytes.

``us_per_call`` is the p95 per-transfer makespan (arrival → completion)
in microseconds; ``derived`` is aggregate goodput in MB/s.  Every mirror
uses deterministic token-bucket pacing, so rows are load-independent
perf signal: ``benchmarks/run.py --check`` guards them at 3x and
additionally enforces the flash-crowd win-guard (robust p95 <= plain
p95 on the gray storm, no p95 regression on the clean burst, hedge
waste <= 5%; see ``_check_flashcrowd_wins``).  Rows land in
``BENCH_online.json``.
"""

from __future__ import annotations

import argparse
import asyncio
import hashlib
import time

import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.chunking import ChunkParams
from repro.transfer import RangeServer, Replica, Throttle, TransferManager

MB = 1024 * 1024

#: mirror rates (MiB/s): one distinctly fast path, two slow — the
#: paper_baseline shape at loopback-friendly scale.
RATES = (24, 8, 8)
#: gray failure: the fast mirror drops to this fraction of its rate —
#: deep enough that its capacity EWMA sinks below the fleet model's
#: probation trip ratio against the surviving 8 MiB/s peers.
DEGRADE_FACTOR = 0.03
#: seconds after the first arrival before the gray degradation lands —
#: early enough to catch most of the storm mid-flight.
DEGRADE_AT = 0.25
#: the storm: every transfer this many bytes, arrivals 0.05 s apart
#: (the ``burst`` trace's grid).
ARRIVAL_STEP = 0.05


def _blob(size: int) -> bytes:
    rng = np.random.default_rng(29)
    return rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()


def _fleet(blob):
    servers = []
    for rate in RATES:
        s = RangeServer(throttle=Throttle(bytes_per_s=rate * MB,
                                          deterministic=True)).start()
        s.add_blob("/data", blob)
        servers.append(s)
    return servers


def _params() -> ChunkParams:
    return ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)


def _manager(replicas, *, robust: bool) -> TransferManager:
    if robust:
        # current defaults (hedging on, probation on) + admission knobs
        return TransferManager(replicas, params=_params(),
                               max_active_transfers=3,
                               max_inflight_bytes=16 * MB)
    # the PR-6-style manager: no hedging, no probation, no admission
    return TransferManager(replicas, params=_params(),
                           hedge_quantile=0.0, probation=False)


def _storm(blob, n: int, *, robust: bool, gray: bool):
    """Run one storm; returns (makespans_s, wall_s, manager)."""
    servers = _fleet(blob)
    want = hashlib.sha256(blob).hexdigest()
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        mgr = _manager(replicas, robust=robust)
        fast = servers[int(np.argmax(RATES))]

        async def one(arrival: float) -> float:
            t0 = time.perf_counter()
            data, _ = await mgr.fetch(len(blob), start_delay=arrival)
            assert hashlib.sha256(bytes(data)).hexdigest() == want, \
                "integrity"
            # makespan = arrival -> completion, excluding the staged delay
            return time.perf_counter() - t0 - arrival

        async def degrade() -> None:
            await asyncio.sleep(DEGRADE_AT)
            fast.set_throttle(Throttle(
                bytes_per_s=max(RATES) * MB * DEGRADE_FACTOR,
                deterministic=True))

        async def go():
            jobs = [one(ARRIVAL_STEP * j) for j in range(n)]
            if gray:
                jobs.append(degrade())
            t0 = time.perf_counter()
            results = await asyncio.gather(*jobs)
            return ([m for m in results if m is not None],
                    time.perf_counter() - t0)

        makespans, wall = asyncio.run(go())
        return makespans, wall, mgr
    finally:
        for s in servers:
            s.stop()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes/reps (CI check mode)")
    args = ap.parse_args(argv)

    size = 3 * MB if args.quick else 6 * MB
    n = 6 if args.quick else 12
    blob = _blob(size)

    for trace, gray in (("burst", False), ("gray", True)):
        waste_row = None
        for label, robust in (("plain", False), ("robust", True)):
            makespans, wall, mgr = _storm(blob, n, robust=robust, gray=gray)
            p95 = float(np.percentile(makespans, 95))
            goodput = n * size / wall / MB
            emit(f"flashcrowd/{trace}/{label}", p95 * 1e6,
                 f"{goodput:.1f}",
                 f"admitted={mgr.admission['admitted']}",
                 f"queued={mgr.admission['queued']}",
                 f"probations={mgr.fleet.probations}")
            if robust and gray:
                wasted = sum(r.hedge_wasted_bytes for r in mgr.reports)
                issued = sum(r.hedges_issued for r in mgr.reports)
                waste_row = (wasted, 100.0 * wasted / (n * size), issued)
        if waste_row is not None:
            wasted, pct, issued = waste_row
            emit("flashcrowd/gray/waste", float(wasted), f"{pct:.2f}",
                 f"hedges_issued={issued}")


if __name__ == "__main__":
    main()
