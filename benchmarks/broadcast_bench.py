"""Peer-assisted checkpoint broadcast: swarm vs N independent restores.

The broadcast claim is that when N nodes restore the SAME checkpoint
from one origin, mounting each restorer's filling buffer on a
:class:`~repro.transfer.PeerMirror` turns the flash crowd into a swarm:
peers fetch de-correlated stripes, advertise them, and serve each other,
so the origin sends each byte roughly once instead of N times and the
crowd's makespan stops scaling with N.  This bench measures that claim
on real loopback sockets:

``broadcast/independent/n4``
    The baseline: N restorers each fetch the whole blob from the origin
    alone.  The origin's deterministic token bucket is ``shared`` (one
    uplink split across connections), so the crowd divides its capacity
    and every restore takes ~N times the solo transfer.

``broadcast/swarm/n4``
    The same N restorers with peer mirrors: restorer ``j`` stripes its
    frontier with ``stripe=(j, N)`` and lists the other restorers'
    mirrors (each behind its own shared-uplink throttle equal to the
    origin's) as partial replicas.  Coverage is polled every 10 ms.

``broadcast/swarm/origin_x``
    Origin egress amplification for the swarm run: bytes the origin
    actually served over the blob size.  The CDTP-style dissemination
    bound is ~1; N independent clients would pay N.

``us_per_call`` is the crowd makespan (first arrival -> last completion)
in microseconds; ``derived`` is that makespan in seconds (for
``origin_x``: the egress ratio).  All throttles are deterministic, so
rows are load-independent perf signal: ``benchmarks/run.py --check``
guards them at 3x and additionally enforces the broadcast win-guard
(swarm makespan <= independent makespan, origin egress <= 1.5x the blob
at N=4; see ``_check_broadcast_wins``).  Rows land in
``BENCH_online.json``.
"""

from __future__ import annotations

import argparse
import asyncio
import hashlib
import time

import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.chunking import ChunkParams
from repro.transfer import (BufferSink, MDTPClient, PeerMirror, RangeServer,
                            Replica, Throttle)

MB = 1024 * 1024

#: every uplink (origin and each peer) paces at this rate, shared across
#: its connections — low enough that the token buckets, not the Python
#: event loop, are the bottleneck at loopback.
RATE = 8 * MB
#: swarm size the win-guard is stated at.
N = 4
#: mid-transfer peer exchange needs swarm-scale geometry: chunks small
#: enough that no single origin grab outlives the peers' ramp-up (the
#: defaults' 4 MiB probe would hand half the blob to every restorer
#: before any mirror had bytes to trade — ``swarm_sweep`` tunes the
#: same way).
PARAMS = ChunkParams(initial_chunk=128 * 1024, large_chunk=256 * 1024,
                     min_chunk=32 * 1024)
COVERAGE_REFRESH_S = 0.01


def _blob(size: int) -> bytes:
    rng = np.random.default_rng(0)
    return rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()


def _throttle() -> Throttle:
    return Throttle(bytes_per_s=RATE, shared=True, deterministic=True)


def _origin(blob: bytes) -> RangeServer:
    s = RangeServer(throttle=_throttle()).start()
    s.add_blob("/data", blob)
    return s


def _client(replicas) -> MDTPClient:
    return MDTPClient(replicas, params=PARAMS,
                      coverage_refresh_s=COVERAGE_REFRESH_S)


def _independent(blob: bytes, n: int) -> tuple[float, int]:
    """n restorers, origin only.  Returns (makespan_s, origin_bytes)."""
    origin = _origin(blob)
    want = hashlib.sha256(blob).hexdigest()
    try:
        rep = Replica("127.0.0.1", origin.port, "/data")

        async def one(j: int) -> None:
            data, _ = await _client([rep]).fetch(len(blob))
            assert hashlib.sha256(bytes(data)).hexdigest() == want, \
                "integrity"

        async def go() -> float:
            t0 = time.perf_counter()
            await asyncio.gather(*(one(j) for j in range(n)))
            return time.perf_counter() - t0

        wall = asyncio.run(go())
        return wall, origin.served_bytes
    finally:
        origin.stop()


def _swarm(blob: bytes, n: int) -> tuple[float, int, list[int]]:
    """n restorers serving each other.  Returns (makespan_s,
    origin_bytes, per-peer served bytes)."""
    origin = _origin(blob)
    want = hashlib.sha256(blob).hexdigest()
    sinks = [BufferSink(len(blob)) for _ in range(n)]
    mirrors = [PeerMirror(s, throttle=_throttle()) for s in sinks]
    try:
        rep = Replica("127.0.0.1", origin.port, "/data")

        async def one(j: int) -> None:
            replicas = [rep] + [m.replica for k, m in enumerate(mirrors)
                                if k != j]
            await _client(replicas).fetch(len(blob), sink=sinks[j],
                                          stripe=(j, n))
            assert hashlib.sha256(bytes(sinks[j])).hexdigest() == want, \
                "integrity"

        async def go() -> float:
            t0 = time.perf_counter()
            await asyncio.gather(*(one(j) for j in range(n)))
            return time.perf_counter() - t0

        wall = asyncio.run(go())
        return wall, origin.served_bytes, [m.served_bytes for m in mirrors]
    finally:
        origin.stop()
        for m in mirrors:
            m.stop()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke sizes (CI check mode)")
    args = ap.parse_args(argv)

    size = 4 * MB if args.quick else 8 * MB
    blob = _blob(size)

    wall_i, origin_i = _independent(blob, N)
    emit(f"broadcast/independent/n{N}", wall_i * 1e6, f"{wall_i:.2f}",
         f"origin_x={origin_i / size:.2f}")

    wall_s, origin_s, peers = _swarm(blob, N)
    emit(f"broadcast/swarm/n{N}", wall_s * 1e6, f"{wall_s:.2f}",
         f"origin_x={origin_s / size:.2f}",
         f"peer_mb={sum(peers) / MB:.1f}")
    emit("broadcast/swarm/origin_x", float(origin_s),
         f"{origin_s / size:.3f}", f"blob_mb={size / MB:g}")


if __name__ == "__main__":
    main()
