"""Fleet-contention replay: shared-fleet manager vs independent greedy
clients.

``repro.transfer.TransferManager`` packs each transfer's rounds into
*residual* replica capacity and re-tunes geometry as the active set
changes.  The pre-manager status quo — what this benchmark calls
``greedy`` — is K independent ``MDTPClient``s that each run the one-shot
fused grid tune against the FULL fleet at their own start and ride those
params to the end, oblivious to the other K-1 transfers consuming the
same mirrors.

The replay mirrors contention the way the simulator stack does
(``repro.core.scenarios.contention_traces``): each mirror's bandwidth is
TCP-fair split across the active transfers, and the trace advances in
*phases* — maximal intervals with a constant active set.  Per phase,
every active transfer's completion rate comes from the round-synchronous
device simulator under its current (C, L) and its fair share; phases end
at the next arrival or first completion.  The manager policy re-plans
each phase with ONE fused ``autotune_batch`` call (a row per active
transfer: its residual share and its remaining bytes) — the same vmapped
lattice ``contention_sweep`` exposes as a per-k ladder.

Derived column = makespan (aggregate completion: seconds until the LAST
transfer finishes); ``mean=`` in the extras is the mean per-transfer
completion time and ``vs_greedy=`` the manager's makespan improvement.
``us_per_call`` is the WARM wall-clock of one full policy replay (all
sweeps/simulations jit-cached — the steady-state planning cost the CI
perf guard compares at 3x tolerance).  Rows land in ``BENCH_online.json``
via ``python -m benchmarks.run --json BENCH_online.json``.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.autotune import autotune_batch, autotune_chunk_params
from repro.core.jax_sim import simulate_transfer
from repro.core.scenarios import ContentionTrace, contention_traces


def replay(trace: ContentionTrace, policy: str):
    """Run one policy through one trace.

    Returns ``(makespan_s, mean_completion_s, retunes, wall_s)``.
    """
    assert policy in ("greedy", "manager")
    bw = [float(s.bandwidth) for s in trace.servers]
    rtt = [float(s.rtt) for s in trace.servers]
    k_total = len(trace.sizes)
    t_wall = time.perf_counter()

    if policy == "greedy":
        # What an unmanaged client does today: one fused solo tune at
        # start, inside the timed window (it IS greedy's planning cost —
        # the manager branch must not pay it, its us_per_call feeds the
        # CI perf guard)
        greedy_params = [autotune_chunk_params(bw, rtt, int(s)).params
                         for s in trace.sizes]

    remaining = [float(s) for s in trace.sizes]
    completion = [0.0] * k_total
    now, retunes = 0.0, 0
    while any(r > 1e-6 for r in remaining):
        active = [j for j in range(k_total)
                  if trace.arrivals[j] <= now + 1e-9 and remaining[j] > 1e-6]
        if not active:
            now = min(trace.arrivals[j] for j in range(k_total)
                      if remaining[j] > 1e-6)
            continue
        k = len(active)
        share = [b / k for b in bw]
        if policy == "manager":
            # one fused vmapped sweep re-plans every active transfer for
            # its residual share and ACTUAL remaining bytes
            res = autotune_batch([share] * k, rtt,
                                 [remaining[j] for j in active])
            params = {j: res[i].params for i, j in enumerate(active)}
            retunes += k
        else:
            params = {j: greedy_params[j] for j in active}
        t_full = {
            j: float(simulate_transfer(share, rtt, remaining[j], params[j],
                                       engine="round").total_time)
            for j in active
        }
        pending = [trace.arrivals[j] for j in range(k_total)
                   if trace.arrivals[j] > now + 1e-9 and remaining[j] > 1e-6]
        dt = min(min(t_full.values()),
                 (min(pending) - now) if pending else float("inf"))
        for j in active:
            remaining[j] = max(remaining[j] * (1.0 - dt / t_full[j]), 0.0)
            if remaining[j] <= 1e-6:
                remaining[j] = 0.0
                completion[j] = now + dt
        now += dt
    return (max(completion), float(np.mean(completion)), retunes,
            time.perf_counter() - t_wall)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="accepted for driver symmetry; the traces are "
                         "already smoke-sized (a few seconds warm)")
    ap.parse_args(argv)

    for trace in contention_traces():
        # warm pass compiles every sweep/sim shape; the timed pass is the
        # steady-state planning cost the perf guard compares
        replay(trace, "greedy")
        replay(trace, "manager")
        t_greedy, mean_g, _, wall_g = replay(trace, "greedy")
        emit(f"contention/{trace.name}/greedy", wall_g * 1e6,
             f"{t_greedy:.2f}", f"mean={mean_g:.2f}",
             f"transfers={len(trace.sizes)}")
        t_mgr, mean_m, retunes, wall_m = replay(trace, "manager")
        gain = (t_greedy - t_mgr) / t_greedy
        emit(f"contention/{trace.name}/manager", wall_m * 1e6,
             f"{t_mgr:.2f}", f"mean={mean_m:.2f}", f"retunes={retunes}",
             f"vs_greedy={gain * 100:+.1f}%")


if __name__ == "__main__":
    main()
