"""Paper Fig. 2: transfer time vs file size for MDTP / static / Aria2 / BT.

Fig. 2a includes disk-write delay, 2b excludes it (the paper's headline
numbers: 64 GB in 445.9 s MDTP vs 516.6 s Aria2, a 13.7% gain).  Our
simulator models the network path, i.e. the 2b regime; a configurable disk
drain rate reproduces the 2a regime.  ``--seeders`` emits the Fig. 2c
active-seeder trace for BitTorrent.
"""

from __future__ import annotations

import argparse

import numpy as np

from .common import GB, emit, run_cells
from repro.core import BitTorrentPolicy, simulate
from repro.core.scenarios import bittorrent_seeders, paper_baseline


def transfer_times(sizes_gb, reps: int, include_bt: bool = True) -> dict:
    servers = paper_baseline()
    out = {}
    for gb in sizes_gb:
        for proto in ("mdtp", "static", "aria2"):
            mean, stderr = run_cells(
                f"fig2b/{proto}/{gb}GB", proto, servers, gb * GB, reps
            )
            out[(proto, gb)] = mean
        if include_bt:
            mean, stderr = run_cells(
                f"fig2a/bittorrent/{gb}GB", "bittorrent",
                bittorrent_seeders(), gb * GB, reps,
            )
            out[("bittorrent", gb)] = mean
        # paper-anchored derived metric: MDTP's improvement over Aria2
        gain = (out[("aria2", gb)] - out[("mdtp", gb)]) / out[("aria2", gb)]
        emit(f"fig2b/mdtp_vs_aria2_gain/{gb}GB", 0.0, f"{gain * 100:.1f}%")
    return out


def seeder_trace(reps: int = 5, size_gb: int = 2, window: float = 5.0) -> None:
    """Fig. 2c: number of seeders actively delivering per time window."""
    for seed in range(reps):
        res = simulate(BitTorrentPolicy(), bittorrent_seeders(), size_gb * GB,
                       seed=seed)
        edges = np.arange(0.0, res.total_time + window, window)
        active = []
        for lo, hi in zip(edges[:-1], edges[1:]):
            servers_active = {
                c.server for c in res.chunks
                if c.length > 0 and c.t_complete > lo and c.t_request < hi
            }
            active.append(len(servers_active))
        emit(
            f"fig2c/active_seeders/seed{seed}", 0.0,
            f"{np.mean(active):.2f}",
            f"min={min(active)}", f"max={max(active)}",
            f"trace={'|'.join(map(str, active))}",
        )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+",
                    default=[1, 2, 4, 8, 16, 32, 64])
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seeders", action="store_true")
    ap.add_argument("--no-bt", action="store_true")
    args = ap.parse_args(argv)
    if args.seeders:
        seeder_trace(reps=args.reps)
    transfer_times(args.sizes, args.reps, include_bt=not args.no_bt)


if __name__ == "__main__":
    main()
