"""Online-tuner scenario replay: static one-shot grid vs MCGrad vs bandit.

The paper's Fig. 6/7 events — a mirror throttled mid-transfer, a latency
step on the fastest path, a mirror dying outright — are exactly where a
one-shot (C, L) choice goes stale.  This harness replays those events as
**wave-synchronous traces** (the checkpoint-restore wave loop's mechanics:
the blob moves in fixed-size waves, each wave a fresh simulated transfer
under the conditions in force at that point of the trace) and compares
tuning policies:

* ``static`` — the fused grid sweep once, on the pre-shift fleet, never
  re-tuned (today's offline default);
* ``grid``   — re-run the grid sweep every wave from measured telemetry;
* ``mcgrad`` — jitter-smoothed Monte-Carlo gradient descent per wave
  (``repro.core.online.MCGradTuner``);
* ``bandit`` — discounted-UCB over grid-seeded arms, rewarded by the
  *measured* wave throughput, drift-reset on fleet changes
  (``repro.core.online.BanditTuner``).

Every policy sees identical information: the same pre-shift seed, then
only what the waves measure (per-replica delivered-bytes/second, wave
throughput).  The derived column is total simulated trace seconds;
``vs_static`` in the extras is the online policy's improvement.  Rows land
in ``BENCH_online.json`` via ``python -m benchmarks.run --json`` (the
driver merges rather than clobbers, so the autotune and online artifacts
can accumulate side by side).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from .common import emit  # noqa: F401  (also wires sys.path to src/)

from repro.core.autotune import autotune_chunk_params
from repro.core.jax_sim import simulate_transfer
from repro.core.online import (
    BanditTuner,
    GridTuner,
    MCGradTuner,
    Telemetry,
    rtt_corrected_bandwidth,
)
from repro.core.scenarios import GB, MBPS, paper_baseline

MB = 1024 * 1024


class ReplayTrace:
    """Piecewise-constant fleet conditions: ``pre`` until ``shift_wave``
    waves have completed, ``post`` after.  A dead replica keeps its slot
    with bandwidth 0.0 (positional identity is what the bandit's drift
    detector keys on).  Each trace carries its own wave calibration: the
    bandwidth events (throttle, death) bite hardest over many short
    waves, while a latency step reshapes the per-wave optimum only when
    waves are long enough for RTT amortization to dominate."""

    def __init__(self, name, bw_pre, rtt_pre, bw_post, rtt_post,
                 shift_wave, total_bytes, wave_bytes):
        self.name = name
        self.bw_pre, self.rtt_pre = tuple(bw_pre), tuple(rtt_pre)
        self.bw_post, self.rtt_post = tuple(bw_post), tuple(rtt_post)
        self.shift_wave = shift_wave
        self.total_bytes = int(total_bytes)
        self.wave_bytes = int(wave_bytes)

    def at(self, wave_i):
        if wave_i >= self.shift_wave:
            return self.bw_post, self.rtt_post
        return self.bw_pre, self.rtt_pre


def make_traces(quick: bool) -> list[ReplayTrace]:
    """The three Fig. 6/7-shaped events on the calibrated FABRIC fleet."""
    servers = paper_baseline(jitter=0.0)
    bw = tuple(float(s.bandwidth) for s in servers)
    rtt = tuple(float(s.rtt) for s in servers)
    fastest = max(range(len(bw)), key=lambda i: bw[i])
    throttled = list(bw)
    throttled[fastest] = 6 * MBPS          # hard throttle, 70 -> 6 MiB/s
    lat = list(rtt)
    lat[fastest] = rtt[fastest] + 0.5      # paper §VII-C: +0.5 s requests
    dead = list(bw)
    dead[fastest] = 0.0                    # mirror death
    return [
        ReplayTrace("throttle", bw, rtt, throttled, rtt,
                    shift_wave=2, total_bytes=2 * GB, wave_bytes=256 * MB),
        ReplayTrace("latency_step", bw, rtt, bw, lat,
                    shift_wave=1, total_bytes=(4 if quick else 6) * GB,
                    wave_bytes=1 * GB),
        ReplayTrace("mirror_death", bw, rtt, dead, rtt,
                    shift_wave=2, total_bytes=2 * GB, wave_bytes=256 * MB),
    ]


def replay(trace: ReplayTrace, tuner):
    """Run one policy through one trace.

    Returns ``(sim_seconds, retunes, wall_seconds)`` — simulated trace
    time, adopted re-tunes, and the policy's own planning wall-clock.
    """
    total_bytes, wave_bytes = trace.total_bytes, trace.wave_bytes
    n = len(trace.bw_pre)
    t_wall = time.perf_counter()
    # Every policy starts from the same information: a one-shot grid tune
    # on the pre-shift fleet (what a prior probing transfer observed).
    seed_tel = Telemetry(trace.bw_pre, trace.rtt_pre, float(wave_bytes))
    params = None
    if tuner is not None:
        params = tuner.update(seed_tel)
    if params is None:
        params = autotune_chunk_params(
            list(trace.bw_pre), list(trace.rtt_pre),
            int(wave_bytes)).params

    moved, elapsed, wave_i, retunes = 0, 0.0, 0, 0
    while moved < total_bytes:
        wave = min(wave_bytes, total_bytes - moved)
        bw, rtt = trace.at(wave_i)
        live = [i for i in range(n) if bw[i] > 0.0]
        res = simulate_transfer([bw[i] for i in live],
                                [rtt[i] for i in live],
                                wave, params, engine="round")
        t = float(res.total_time)
        bps = np.asarray(res.bytes_per_server)
        elapsed += t
        moved += wave
        wave_i += 1
        if tuner is not None and moved < total_bytes:
            # Telemetry as an RTT-aware client estimator reports it: the
            # per-request reading is s / (rtt + s / bw) (the estimator's
            # elapsed window spans the request round-trip), then the
            # separately-measured RTT inverts the bias back to the line
            # rate — the same correction ``rtt_corrected_bandwidth``
            # offers the real client.
            reqs = np.asarray(res.requests_per_server)
            obs = [0.0] * n
            for k, i in enumerate(live):
                b, r = float(bps[k]), int(reqs[k])
                if b <= 0.0 or r <= 0:
                    continue
                s = b / r
                per_request = s / (rtt[i] + s / bw[i])
                obs[i] = rtt_corrected_bandwidth(per_request, rtt[i], s)
            new = tuner.update(Telemetry(
                bandwidth=tuple(obs), rtt=tuple(rtt),
                remaining_bytes=float(min(wave_bytes, total_bytes - moved)),
                measured_throughput=wave / max(t, 1e-9),
                elapsed=elapsed))
            if new is not None:
                if new != params:
                    retunes += 1
                params = new
    return elapsed, retunes, time.perf_counter() - t_wall


def make_policies(quick: bool) -> dict:
    return {
        "grid": GridTuner(),
        "mcgrad": MCGradTuner(
            steps=25 if quick else 40,
            n_seeds=6 if quick else 8,
            max_rounds=192),
        "bandit": BanditTuner(n_arms=3),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter trace, fewer MC seeds / descent steps")
    args = ap.parse_args(argv)

    for trace in make_traces(args.quick):
        t_static, _, wall = replay(trace, None)
        emit(f"online/{trace.name}/static", wall * 1e6, f"{t_static:.2f}",
             f"waves={-(-trace.total_bytes // trace.wave_bytes)}",
             f"wave_mb={trace.wave_bytes // MB}",
             f"shift_wave={trace.shift_wave}")
        for pname, tuner in make_policies(args.quick).items():
            t, retunes, wall = replay(trace, tuner)
            gain = (t_static - t) / t_static
            emit(f"online/{trace.name}/{pname}", wall * 1e6, f"{t:.2f}",
                 f"retunes={retunes}", f"vs_static={gain * 100:+.1f}%")


if __name__ == "__main__":
    main()
