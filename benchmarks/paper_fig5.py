"""Paper Fig. 5: replica utilization and request balance.

* 5a — % of replicas used per protocol per file size (MDTP/static: 100%;
  Aria2: 83%, de-minimis cut 1% of file, reported).
* 5b — packets per replica, 32 GB: Aria2 overloads the fastest and parks
  the slowest; MDTP/static are balanced.
* 5c — request count + mean request size per replica, 32 GB, on the
  near-homogeneous preset (the paper's testbed regime where it measured an
  equal 37 requests per replica): MDTP equalizes request *counts* while
  varying *sizes*; static varies counts with fixed sizes.
"""

from __future__ import annotations

import argparse

import numpy as np

from .common import GB, POLICIES, emit
from repro.core import simulate
from repro.core.scenarios import paper_balanced, paper_baseline


def utilization(sizes_gb, reps: int) -> None:
    servers = paper_baseline()
    for gb in sizes_gb:
        for proto in ("mdtp", "static", "aria2"):
            utils = []
            for seed in range(reps):
                r = simulate(POLICIES[proto](), servers, gb * GB, seed=seed)
                utils.append(r.utilization(min_frac=0.01))
            emit(f"fig5a/utilization/{proto}/{gb}GB", 0.0,
                 f"{np.mean(utils) * 100:.0f}%", "min_frac=0.01")


def packets(size_gb: int, seed: int) -> None:
    servers = paper_baseline()
    for proto in ("mdtp", "static", "aria2"):
        r = simulate(POLICIES[proto](), servers, size_gb * GB, seed=seed)
        emit(f"fig5b/packets/{proto}/{size_gb}GB", 0.0,
             "|".join(str(p) for p in r.packets_per_server))


def request_balance(size_gb: int, seed: int) -> None:
    servers = paper_balanced()
    for proto in ("mdtp", "static"):
        r = simulate(POLICIES[proto](), servers, size_gb * GB, seed=seed)
        counts = r.requests_per_server
        mean_sizes = [
            int(np.mean(r.request_sizes(i)) / (1024 * 1024))
            if r.request_sizes(i) else 0
            for i in range(r.n_servers)
        ]
        emit(f"fig5c/request_counts/{proto}/{size_gb}GB", 0.0,
             "|".join(map(str, counts)))
        emit(f"fig5c/request_sizes_mb/{proto}/{size_gb}GB", 0.0,
             "|".join(map(str, mean_sizes)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[1, 4, 16, 32])
    ap.add_argument("--balance-size", type=int, default=32)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    utilization(args.sizes, args.reps)
    packets(args.balance_size, args.seed)
    request_balance(args.balance_size, args.seed)


if __name__ == "__main__":
    main()
