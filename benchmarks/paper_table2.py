"""Paper Table II: the (initial C, large L) chunk-size grid.

The paper swept C in {2,4,8,16} MB with L in {2.5C, 5C, 10C, 20C}-style
pairings per file size and bolded the winners (4/40 MB for <= 8 GB,
16/160 MB above).  We rerun that grid on the calibrated testbed with the
Python simulator and also report the on-device autotuner's pick
(``repro.core.autotune`` — the paper's §VIII-A future work), which searches
the same grid via one vmapped JAX call per candidate.
"""

from __future__ import annotations

import argparse

import numpy as np

from .common import GB, emit
from repro.core import ChunkParams, MDTPPolicy, simulate
from repro.core.autotune import autotune_chunk_params, default_grid
from repro.core.scenarios import paper_baseline

MB = 1024 * 1024


def sweep(file_gb: int, reps: int) -> tuple:
    servers = paper_baseline()
    best = (None, float("inf"))
    for c, l in default_grid():
        params = ChunkParams(initial_chunk=c, large_chunk=l)
        ts = [
            simulate(MDTPPolicy(params=params), servers, file_gb * GB, seed=s).total_time
            for s in range(reps)
        ]
        mean = float(np.mean(ts))
        emit(f"table2/C{c // MB}MB_L{l // MB}MB/{file_gb}GB", 0.0, f"{mean:.2f}")
        if mean < best[1]:
            best = ((c, l), mean)
    (c, l), t = best
    emit(f"table2/best/{file_gb}GB", 0.0, f"{t:.2f}", f"C={c // MB}MB", f"L={l // MB}MB")
    return best


def autotuned(file_gb: int) -> None:
    bw = [s.bandwidth for s in paper_baseline()]
    res = autotune_chunk_params(bw, 0.03, file_gb * GB)
    emit(
        f"table2/autotune/{file_gb}GB", 0.0, f"{res.predicted_time:.2f}",
        f"C={res.params.initial_chunk // MB}MB",
        f"L={res.params.large_chunk // MB}MB",
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[2, 32])
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--no-autotune", action="store_true")
    args = ap.parse_args(argv)
    for gb in args.sizes:
        sweep(gb, args.reps)
        if not args.no_autotune:
            autotuned(gb)


if __name__ == "__main__":
    main()
