"""Roofline report over the dry-run artifacts (task §Roofline).

Reads ``results/dryrun.jsonl`` (written by ``repro.launch.dryrun``) and
prints the three-term roofline per (arch x shape x mesh) plus bottleneck
and useful-FLOPs ratio.  ``--reanalyze`` re-walks the gzipped HLO archives
with the current ``hlo_analysis`` walker (no recompilation) and rewrites
the records — the perf-iteration loop uses this after walker refinements.
"""

from __future__ import annotations

import argparse
import glob
import gzip
import json
import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in (os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, os.path.abspath(_SRC))

from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = "results/dryrun.jsonl"
HLO_DIR = "results/hlo"


def load(results=RESULTS) -> list[dict]:
    recs = {}
    with open(results) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            recs[(r["arch"], r["shape"], r["mesh"], r.get("variant"))] = r
    return list(recs.values())


def reanalyze(recs: list[dict], hlo_dir=HLO_DIR) -> list[dict]:
    out = []
    for r in recs:
        if r.get("status") != "ok":
            out.append(r)
            continue
        suffix = f"_{r['variant']}" if r.get("variant") else ""
        path = os.path.join(
            hlo_dir, f"{r['arch']}_{r['shape']}_{r['mesh']}{suffix}.hlo.gz")
        if not os.path.exists(path):
            out.append(r)
            continue
        with gzip.open(path, "rt") as f:
            cost = analyze_hlo(f.read())
        terms = {
            "compute_s": cost.flops / PEAK_FLOPS,
            "memory_s": cost.bytes_accessed / HBM_BW,
            "collective_s": cost.collective_bytes / ICI_BW,
        }
        bott = max(terms, key=terms.get)
        r = dict(r)
        r["hlo_walk"] = {
            "flops_per_dev": cost.flops,
            "hbm_bytes_per_dev": cost.bytes_accessed,
            "collective_bytes_per_dev": cost.collective_bytes,
            "collectives": {k: int(v) for k, v in cost.collectives.items()},
            "collective_count": cost.collective_count,
            "unparsed_while": cost.unparsed_while,
            "copy_bytes_per_dev": cost.copy_bytes,
            "elided_bytes_per_dev": cost.elided_bytes,
        }
        mf = r["roofline"]["model_flops_global"]
        n_chips = r["n_chips"]
        r["roofline"] = {
            **{k: round(v, 6) for k, v in terms.items()},
            "bottleneck": bott.replace("_s", ""),
            "model_flops_global": mf,
            "useful_flops_ratio": round(
                (mf / n_chips) / cost.flops, 4) if cost.flops else 0.0,
            "params_total": r["roofline"]["params_total"],
            "params_active": r["roofline"]["params_active"],
        }
        out.append(r)
    return out


def report(recs: list[dict], mesh: str = "16x16") -> None:
    print("name,us_per_call,derived,compute_s,memory_s,collective_s,"
          "bottleneck,roofline_frac,useful_ratio,fits_16gb")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") == "skipped" and r["mesh"] == mesh:
            print(f"roofline/{r['arch']}/{r['shape']}/{mesh},0.0,skipped,"
                  f",,,,,,")
            continue
        if r.get("status") != "ok" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / dom if dom > 0 else 0.0
        print(
            f"roofline/{r['arch']}/{r['shape']}/{mesh},0.0,"
            f"{rf['bottleneck']},"
            f"{rf['compute_s']:.4f},{rf['memory_s']:.4f},"
            f"{rf['collective_s']:.4f},{rf['bottleneck']},"
            f"{frac:.4f},{rf['useful_flops_ratio']:.4f},"
            f"{r['memory'].get('fits_16gb')}")


def report_main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--hlo-dir", default=HLO_DIR)
    ap.add_argument("--reanalyze", action="store_true")
    ap.add_argument("--write", default=None,
                    help="rewrite records to this jsonl after --reanalyze")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    if not os.path.exists(args.results):
        print(f"# roofline: no dry-run results at {args.results} "
              "(run python -m repro.launch.dryrun --all first)")
        return
    recs = load(args.results)
    if args.reanalyze:
        recs = reanalyze(recs, args.hlo_dir)
        if args.write:
            with open(args.write, "w") as f:
                for r in recs:
                    f.write(json.dumps(r) + "\n")
    report(recs, mesh=args.mesh)


if __name__ == "__main__":
    report_main()
