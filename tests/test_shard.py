"""Sharded, work-stealing restore: planning, the steal ledger, the
K-host socket orchestration, and ``restore_checkpoint(shard_plan=)``.
"""

import asyncio
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.chunking import ChunkParams
from repro.transfer import RangeServer, Replica, Throttle
from repro.transfer.shard import (ShardPlan, StealLedger, fetch_sharded,
                                  manifest_boundaries, plan_for_mesh,
                                  plan_shards)

KB = 1024
MB = 1024 * 1024


def _blob(n: int, seed: int = 3) -> bytes:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()


# --------------------------------------------------------------------------
# planning
# --------------------------------------------------------------------------

def test_plan_shards_even_split():
    plan = plan_shards(100, 4)
    assert plan.spans == ((0, 25), (25, 50), (50, 75), (75, 100))
    assert plan.n_hosts == 4
    assert plan.nbytes_of(2) == 25
    assert plan.host_of(0) == 0 and plan.host_of(99) == 3


def test_plan_shards_covers_exactly_once():
    for k in (1, 2, 3, 5, 8):
        plan = plan_shards(1000, k)
        assert plan.spans[0][0] == 0 and plan.spans[-1][1] == 1000
        for (s0, e0), (s1, e1) in zip(plan.spans, plan.spans[1:]):
            assert e0 == s1 and s0 <= e0 and s1 <= e1


def test_plan_shards_snaps_to_boundaries():
    # ideal cuts at 25/50/75 snap to the nearest legal leaf start; the
    # snapping is monotone so spans never invert even with clustered
    # boundaries
    plan = plan_shards(100, 4, boundaries=[10, 30, 48, 52, 90])
    assert plan.spans == ((0, 30), (30, 48), (48, 90), (90, 100))
    for s, e in plan.spans:
        assert s <= e
    # every interior cut is a legal boundary
    for s, _ in plan.spans[1:]:
        assert s in (10, 30, 48, 52, 90)


def test_plan_shards_more_hosts_than_boundaries():
    # K=4 but only one legal cut: some hosts own empty spans, coverage
    # is still exact
    plan = plan_shards(100, 4, boundaries=[60])
    assert plan.spans[0][0] == 0 and plan.spans[-1][1] == 100
    assert sum(e - s for s, e in plan.spans) == 100
    assert any(s == e for s, e in plan.spans)


def test_manifest_boundaries_and_mesh_plan(tmp_path):
    state = {"a": jnp.zeros((17,), jnp.float32),
             "b": jnp.ones((31,), jnp.float32),
             "c": jnp.arange(11, dtype=jnp.int32)}
    d = save_checkpoint(str(tmp_path), 1, state)
    import json
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    bnd = manifest_boundaries(manifest)
    starts = sorted(int(e["offset"]) for e in manifest["leaves"])
    assert list(bnd) == [s for s in starts if s > 0]

    class FakeMesh:
        shape = {"data": 2, "model": 1}

    total = int(manifest["total_bytes"])
    plan = plan_for_mesh(total, FakeMesh(), axis="data", boundaries=bnd)
    assert plan.n_hosts == 2
    cut = plan.spans[0][1]
    assert cut in bnd  # tensors stay whole on one host


# --------------------------------------------------------------------------
# the steal ledger
# --------------------------------------------------------------------------

def test_ledger_steals_tail_of_most_backlogged():
    plan = plan_shards(4 * MB, 4)
    ledger = StealLedger(plan, min_steal=64 * KB)
    # host 2 has the big backlog; others are nearly done
    backlog = {0: [(0, 32 * KB)], 1: [], 2: [(1 * MB + MB // 2, MB // 2)],
               3: [(3 * MB, 16 * KB)]}
    grab = ledger.steal(0, lambda h: backlog[h])
    assert grab is not None
    victim, s, e = grab
    assert victim == 2
    # the TAIL half of the gap, so the victim's own frontier eats the head
    assert e == 2 * MB and s == 2 * MB - MB // 4
    assert ledger.stolen_bytes == MB // 4


def test_ledger_claims_do_not_overlap_and_release_reopens():
    plan = plan_shards(2 * MB, 2)
    ledger = StealLedger(plan, min_steal=64 * KB)
    uncovered = {0: [], 1: [(1 * MB, 1 * MB)]}
    g1 = ledger.steal(0, lambda h: uncovered[h])
    g2 = ledger.steal(0, lambda h: uncovered[h])
    assert g1 and g2
    (_, s1, e1), (_, s2, e2) = g1, g2
    assert min(e1, e2) <= max(s1, s2)          # disjoint claims
    ledger.release(1, s1, e1)
    # the released span is stealable again (its tail goes first, as ever)
    g3 = ledger.steal(0, lambda h: uncovered[h])
    assert g3 is not None and s1 <= g3[1] < g3[2] == e1


def test_ledger_sizes_claim_from_thief_bandwidth():
    plan = plan_shards(8 * MB, 2)
    ledger = StealLedger(plan, min_steal=64 * KB, claim_horizon_s=2.0)
    uncovered = {0: [], 1: [(4 * MB, 4 * MB)]}
    # fast thief: 1 MB/s over a 2 s horizon -> a 2 MB tail claim,
    # not the static half-gap
    grab = ledger.steal(0, lambda h: uncovered[h], thief_bw=1.0 * MB)
    assert grab == (1, 6 * MB, 8 * MB)
    # slow thief: bandwidth-sized claim clamps up to min_steal and
    # still comes off the (remaining) tail
    grab2 = ledger.steal(0, lambda h: uncovered[h], thief_bw=1.0 * KB)
    assert grab2 == (1, 6 * MB - 64 * KB, 6 * MB)
    # absurd bandwidth is clamped to the whole gap
    g = StealLedger(plan, min_steal=64 * KB).steal(
        0, lambda h: uncovered[h], thief_bw=1e12)
    assert g == (1, 4 * MB, 8 * MB)
    # no bandwidth sample: static steal_frac fallback (tail half)
    g0 = StealLedger(plan, min_steal=64 * KB).steal(
        0, lambda h: uncovered[h])
    assert g0 == (1, 6 * MB, 8 * MB)


def test_ledger_respects_min_steal_floor():
    plan = plan_shards(1 * MB, 2)
    ledger = StealLedger(plan, min_steal=256 * KB)
    # backlog below the floor: not worth a connection
    assert ledger.steal(0, lambda h: [] if h == 0
                        else [(512 * KB, 128 * KB)]) is None
    # a gap smaller than 2*min_steal is taken whole, not split
    grab = ledger.steal(0, lambda h: [] if h == 0
                        else [(512 * KB, 384 * KB)])
    assert grab is not None
    _, s, e = grab
    assert (s, e) == (512 * KB, 512 * KB + 384 * KB)


# --------------------------------------------------------------------------
# fetch_sharded on real sockets
# --------------------------------------------------------------------------

def _origin(blob, rate):
    s = RangeServer(throttle=Throttle(bytes_per_s=rate, shared=True,
                                      deterministic=True)).start()
    s.add_blob("/data", blob)
    return s


def _run_sharded(blob, k, rates, steal):
    plan = plan_shards(len(blob), k)
    servers = [_origin(blob, r) for r in rates]
    try:
        origins = [[Replica("127.0.0.1", servers[h].port, "/data")]
                   for h in range(k)]
        res = asyncio.run(fetch_sharded(
            len(blob), plan, origins, steal=steal,
            client_kw=dict(params=ChunkParams(32 * KB, 64 * KB,
                                              min_chunk=8 * KB),
                           coverage_refresh_s=0.01)))
    finally:
        for s in servers:
            s.stop()
    for h in range(k):
        s, e = plan.span_of(h)
        assert hashlib.sha256(bytes(res.sinks[h])[s:e]).hexdigest() == \
            hashlib.sha256(blob[s:e]).hexdigest(), f"host {h} span"
    return res


def test_fetch_sharded_lands_every_span():
    blob = _blob(1 * MB)
    res = _run_sharded(blob, 3, [64 * MB] * 3, steal=True)
    assert res.stolen_bytes == 0 or res.makespan > 0  # balanced: no need
    assert len(res.reports) == 3 and all(r for r in res.reports)


def test_fetch_sharded_steals_from_straggler():
    # host 0's origin at 1/16 of the others: the fast hosts must claim
    # parts of its span (theft witness > 0) and all spans still verify
    blob = _blob(2 * MB)
    res = _run_sharded(blob, 3, [2 * MB, 32 * MB, 32 * MB], steal=True)
    assert res.stolen_bytes > 0
    assert all(s.victim == 0 for s in res.steals)
    thieves = {s.thief for s in res.steals}
    assert thieves and 0 not in thieves


def test_fetch_sharded_steal_off_is_independent():
    blob = _blob(512 * KB)
    res = _run_sharded(blob, 2, [16 * MB, 16 * MB], steal=False)
    assert res.stolen_bytes == 0 and res.steals == []


# --------------------------------------------------------------------------
# restore_checkpoint(shard_plan=)
# --------------------------------------------------------------------------

def _serve_checkpoint(d, step, rate=64 * MB):
    s = RangeServer(throttle=Throttle(bytes_per_s=rate)).start()
    base = f"/ckpt/step_{step:010d}"
    s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
    s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
    return s


def test_restore_shard_plan_restores_only_own_span(tmp_path):
    state = {"params": {"w": jax.random.normal(jax.random.PRNGKey(0),
                                               (128, 128)),
                        "b": jnp.arange(128, dtype=jnp.float32)},
             "step": jnp.int32(9)}
    d = save_checkpoint(str(tmp_path), 9, state)
    srv = _serve_checkpoint(d, 9)
    try:
        reps = [Replica("127.0.0.1", srv.port, "/ckpt")]
        halves = [restore_checkpoint(str(tmp_path), state, step=9,
                                     replicas=reps, shard_plan=(h, 2))[0]
                  for h in (0, 1)]
    finally:
        srv.stop()
    want = jax.tree.leaves(state)
    for leaf_idx in range(len(want)):
        pieces = [jax.tree.leaves(halves[h], is_leaf=lambda x: x is None)
                  [leaf_idx] for h in (0, 1)]
        held = [p for p in pieces if p is not None]
        # each leaf is restored by EXACTLY one host (cuts snap to leaf
        # boundaries, so no leaf straddles the shard cut)
        assert len(held) == 1, f"leaf {leaf_idx} held by {len(held)} hosts"
        assert np.array_equal(np.asarray(held[0]),
                              np.asarray(want[leaf_idx]))


def test_restore_shard_plan_int_k_matches_explicit_plan(tmp_path):
    import json
    state = {"w": jnp.ones((64, 64), jnp.float32),
             "v": jnp.zeros((32,), jnp.float32)}
    d = save_checkpoint(str(tmp_path), 2, state)
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    plan = plan_shards(int(manifest["total_bytes"]), 2,
                       manifest_boundaries(manifest))
    srv = _serve_checkpoint(d, 2)
    try:
        reps = [Replica("127.0.0.1", srv.port, "/ckpt")]
        via_k, _ = restore_checkpoint(str(tmp_path), state, step=2,
                                      replicas=reps, shard_plan=(0, 2))
        via_plan, _ = restore_checkpoint(str(tmp_path), state, step=2,
                                         replicas=reps,
                                         shard_plan=(0, plan))
    finally:
        srv.stop()
    a = jax.tree.leaves(via_k, is_leaf=lambda x: x is None)
    b = jax.tree.leaves(via_plan, is_leaf=lambda x: x is None)
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert (x is None) == (y is None)
        if x is not None:
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_shard_traces_scenarios():
    from repro.core.scenarios import shard_traces
    traces = shard_traces()
    names = [t.name for t in traces]
    assert "balanced" in names and "straggler" in names
    for t in traces:
        assert t.k >= 2 and len(t.servers) == t.k and t.size > 0
