"""Unit + property tests for the MDTP bin-packing allocator (paper §IV-B)."""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.chunking import (
    MB,
    ChunkParams,
    default_chunk_params,
    fast_server_mask,
    geometric_mean,
    next_chunk_size,
    round_chunk_sizes,
)

# ---------------------------------------------------------------- unit tests


def test_table2_defaults():
    """Paper Table II: 4/40 MB up to 8 GB, 16/160 MB above."""
    small = default_chunk_params(1024**3)
    assert (small.initial_chunk, small.large_chunk) == (4 * MB, 40 * MB)
    edge = default_chunk_params(8 * 1024**3)
    assert (edge.initial_chunk, edge.large_chunk) == (4 * MB, 40 * MB)
    big = default_chunk_params(8 * 1024**3 + 1)
    assert (big.initial_chunk, big.large_chunk) == (16 * MB, 160 * MB)


def test_geometric_mean_matches_numpy():
    ths = [12.0, 14.0, 15.0, 16.0, 18.0, 70.0]
    expected = float(np.exp(np.mean(np.log(ths))))
    assert math.isclose(geometric_mean(ths), expected, rel_tol=1e-12)


def test_geometric_mean_ignores_unprobed():
    assert geometric_mean([0.0, 0.0, 8.0, 2.0]) == pytest.approx(4.0)
    assert geometric_mean([0.0, 0.0]) == 0.0


def test_fast_mask_max_is_always_fast():
    ths = [1.0, 2.0, 100.0]
    mask = fast_server_mask(ths)
    assert mask[2] is True or mask[2] == True  # noqa: E712
    # all-equal: everyone fast
    assert all(fast_server_mask([5.0, 5.0, 5.0]))


def test_unprobed_server_gets_initial_chunk():
    p = ChunkParams(initial_chunk=4 * MB, large_chunk=40 * MB)
    assert next_chunk_size(0, [0.0, 50.0], p, 10**12) == 4 * MB


def test_fastest_gets_large_chunk():
    p = ChunkParams(initial_chunk=4 * MB, large_chunk=40 * MB)
    assert next_chunk_size(1, [10.0, 50.0], p, 10**12) == 40 * MB


def test_proportional_sizing():
    """C_i = (L / th_max) * th_i  (paper §IV-B equation)."""
    p = ChunkParams(initial_chunk=4 * MB, large_chunk=40 * MB)
    ths = [10.0, 25.0, 50.0]
    assert next_chunk_size(0, ths, p, 10**12) == round(40 * MB * 10 / 50)
    assert next_chunk_size(1, ths, p, 10**12) == round(40 * MB * 25 / 50)
    assert next_chunk_size(2, ths, p, 10**12) == 40 * MB


def test_min_chunk_floor_and_remaining_clamp():
    p = ChunkParams(initial_chunk=4 * MB, large_chunk=40 * MB, min_chunk=64 * 1024)
    # glacial server: proportional size would be ~40 bytes -> floored
    assert next_chunk_size(0, [1e-6, 50.0], p, 10**12) == 64 * 1024
    # clamp to remaining
    assert next_chunk_size(1, [10.0, 50.0], p, 1000) == 1000
    assert next_chunk_size(1, [10.0, 50.0], p, 0) == 0


def test_fast_get_large_mode():
    """Algorithm 1 pseudocode: every server >= GM gets L."""
    p = ChunkParams(4 * MB, 40 * MB, mode="fast_get_large")
    ths = [10.0, 30.0, 50.0]  # GM ~= 24.7
    assert next_chunk_size(1, ths, p, 10**12) == 40 * MB  # fast but not fastest
    assert next_chunk_size(0, ths, p, 10**12) == round(40 * MB * 10 / 50)


def test_round_chunk_sizes_consistency():
    p = ChunkParams(4 * MB, 40 * MB)
    ths = [0.0, 10.0, 50.0]
    sizes = round_chunk_sizes(ths, p, 10**12)
    assert sizes == [next_chunk_size(i, ths, p, 10**12) for i in range(3)]


def test_invalid_params_rejected():
    with pytest.raises(ValueError):
        ChunkParams(initial_chunk=0, large_chunk=1)
    with pytest.raises(ValueError):
        ChunkParams(mode="bogus")


# ------------------------------------------------------------ property tests

_throughputs = st.lists(
    st.one_of(st.just(0.0), st.floats(min_value=0.1, max_value=1e9)),
    min_size=1, max_size=12,
)
_params = st.builds(
    ChunkParams,
    initial_chunk=st.integers(64 * 1024, 64 * MB),
    large_chunk=st.integers(64 * 1024, 640 * MB),
    min_chunk=st.integers(1024, 64 * 1024),
    mode=st.sampled_from(["proportional", "fast_get_large"]),
)


@settings(max_examples=200, deadline=None)
@given(ths=_throughputs, params=_params, remaining=st.integers(0, 2**40))
def test_size_bounds(ths, params, remaining):
    """0 <= size <= remaining, and size <= max(L, C, min_chunk)."""
    for i in range(len(ths)):
        size = next_chunk_size(i, ths, params, remaining)
        assert 0 <= size <= remaining
        assert size <= max(params.large_chunk, params.initial_chunk,
                           params.min_chunk)


@settings(max_examples=200, deadline=None)
@given(ths=_throughputs, params=_params)
def test_probed_servers_never_starve(ths, params):
    """With plenty remaining, every server gets at least min_chunk."""
    remaining = 2**41
    for i in range(len(ths)):
        size = next_chunk_size(i, ths, params, remaining)
        if ths[i] > 0:
            assert size >= params.min_chunk
        else:
            assert size == min(params.initial_chunk, remaining)


@settings(max_examples=200, deadline=None)
@given(
    others=st.lists(st.floats(min_value=0.1, max_value=1e6), min_size=1, max_size=8),
    lo=st.floats(min_value=0.1, max_value=1e6),
    hi=st.floats(min_value=0.1, max_value=1e6),
)
def test_monotone_in_throughput(others, lo, hi):
    """A faster observation never yields a smaller next chunk (proportional)."""
    lo, hi = min(lo, hi), max(lo, hi)
    p = ChunkParams(4 * MB, 40 * MB)
    remaining = 2**41
    s_lo = next_chunk_size(0, [lo] + others, p, remaining)
    s_hi = next_chunk_size(0, [hi] + others, p, remaining)
    assert s_hi >= s_lo


@settings(max_examples=150, deadline=None)
@given(ths=st.lists(st.floats(min_value=0.1, max_value=1e6), min_size=2, max_size=8))
def test_bin_packing_deadline_property(ths):
    """The paper's core invariant: every proportional chunk finishes within
    (about) the fastest server's large-chunk time T = L / th_max."""
    p = ChunkParams(4 * MB, 40 * MB, min_chunk=1)
    T = p.large_chunk / max(ths)
    for i, th in enumerate(ths):
        size = next_chunk_size(i, ths, p, 2**41)
        # round() adds at most 0.5 bytes -> up to 0.5/th seconds
        assert size / th <= T + 0.5 / th + 1e-9


@settings(max_examples=100, deadline=None)
@given(ths=st.lists(st.floats(min_value=0.01, max_value=1e8), min_size=1, max_size=10))
def test_gm_between_min_and_max(ths):
    gm = geometric_mean(ths)
    assert min(ths) * 0.999 <= gm <= max(ths) * 1.001
    mask = fast_server_mask(ths)
    # the max-throughput server is always classified fast
    assert mask[int(np.argmax(ths))]
