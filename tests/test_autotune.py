"""Tuner-fusion tests: the traced-chunk-params sweep must be a drop-in
replacement for the old per-point grid search — one compile, same argmin,
same times — and the traced simulator must still track the Python one."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.autotune import (  # noqa: E402
    _fused_sweep,
    autotune_batch,
    autotune_chunk_params,
    default_grid,
    sweep_scenarios,
)
from repro.core.chunking import ChunkParams  # noqa: E402
from repro.core.jax_alloc import ChunkArrays, chunk_sizes  # noqa: E402
from repro.core.jax_sim import SimConfig, simulate_static, simulate_transfer  # noqa: E402
from repro.core.mdtp import MDTPPolicy  # noqa: E402
from repro.core.simulator import ServerSpec, simulate  # noqa: E402
from repro.core.static_chunking import StaticChunkingPolicy  # noqa: E402

MB = 1024 * 1024
GB = 1024 * MB

BW = [50.0 * MB, 30.0 * MB, 10.0 * MB, 80.0 * MB]


def test_fused_sweep_single_compile():
    """An arbitrary (C, L) grid costs exactly ONE jit compile — chunk sizes
    are traced data, not static args, so no per-grid-point retrace."""
    jax.clear_caches()
    assert _fused_sweep._cache_size() == 0
    grid = [(c * MB, l * MB) for c in (1, 2, 3, 5, 7) for l in (16, 32, 64)]
    autotune_chunk_params(BW, 0.03, 2 * GB, grid=grid)
    assert _fused_sweep._cache_size() == 1
    # different grid VALUES (same shape) must hit the same executable
    grid2 = [(c * 2, l * 2) for c, l in grid]
    autotune_chunk_params(BW, 0.03, 4 * GB, grid=grid2, n_seeds=1)
    assert _fused_sweep._cache_size() == 1


@pytest.mark.parametrize("engine", ["event", "round", "scan"])
def test_fused_matches_per_point(engine):
    """Fused vmapped sweep == the old per-point evaluation under EVERY
    engine: same predicted time per grid point (float tolerance) and same
    argmin."""
    cfg = (SimConfig(max_rounds=2048) if engine == "scan" else SimConfig())
    res = autotune_chunk_params(BW, 0.03, 2 * GB, engine=engine)
    per_point = [
        float(simulate_transfer(BW, 0.03, 2 * GB, ChunkParams(c, l),
                                config=cfg, engine=engine).total_time)
        for c, l in default_grid()
    ]
    np.testing.assert_allclose(res.predicted_times, per_point, rtol=1e-6)
    assert np.argmin(res.predicted_times) == np.argmin(per_point)
    best_c, best_l = default_grid()[int(np.argmin(per_point))]
    assert (res.params.initial_chunk, res.params.large_chunk) == (best_c, best_l)


def test_round_engine_tracks_event_engine_on_grid():
    """The O(#rounds) sweep approximates the O(#chunks) sweep: same argmin
    on the Table II grid, every grid point within a documented 8% (exact
    on the paper's C == L/10 geometry, loosest at probe-heavy C >= L/2.5
    where server clocks desync by multiple rounds)."""
    res_r = autotune_chunk_params(BW, 0.03, 2 * GB, engine="round")
    res_e = autotune_chunk_params(BW, 0.03, 2 * GB, engine="event")
    assert res_r.params == res_e.params
    np.testing.assert_allclose(
        res_r.predicted_times, res_e.predicted_times, rtol=0.08)
    # the default Table II pairing (C = L/10) is where the round
    # assumption is exact — these grid points must agree tightly
    for (c, l), tr, te in zip(default_grid(), res_r.predicted_times,
                              res_e.predicted_times):
        if l == 10 * c:
            assert tr == pytest.approx(te, rel=2e-3), (c, l)


def test_fused_matches_per_point_monte_carlo():
    """Seed-averaged (jitter) sweep == per-point seed-vmapped means."""
    cfg = SimConfig(jitter=0.2)
    grid = default_grid()[:6]
    res = autotune_chunk_params(BW, 0.03, 2 * GB, grid=grid,
                                jitter=0.2, n_seeds=4, engine="event")
    for (c, l), t_fused in zip(grid, res.predicted_times):
        ts = [float(simulate_transfer(BW, 0.03, 2 * GB, ChunkParams(c, l),
                                      seed=s, config=cfg).total_time)
              for s in range(4)]
        assert t_fused == pytest.approx(float(np.mean(ts)), rel=1e-5)


def test_traced_params_match_python_sim():
    """Traced-chunk-params simulate_transfer still cross-checks against the
    Python discrete-event simulator."""
    rates = [20.0, 35.0, 7.5, 55.0]
    rtt, size = 0.02, 256 * MB
    params = ChunkParams(2 * MB, 20 * MB)
    specs = [ServerSpec(name=f"s{i}", bandwidth=r * MB, rtt=rtt)
             for i, r in enumerate(rates)]
    py = simulate(MDTPPolicy(params=params), specs, size, seed=0)
    jx = simulate_transfer([r * MB for r in rates], rtt, size, params)
    assert float(jx.total_time) == pytest.approx(py.total_time, rel=0.02)
    np.testing.assert_allclose(
        np.asarray(jx.bytes_per_server), np.asarray(py.bytes_per_server),
        rtol=0.05, atol=2 * params.large_chunk)


def test_static_mode_matches_python_sim():
    """simulate_static (now the C == L == chunk fold of the adaptive path)
    still matches the Python static-chunking policy."""
    rates = [20.0, 35.0, 7.5]
    rtt, size, chunk = 0.02, 256 * MB, 8 * MB
    specs = [ServerSpec(name=f"s{i}", bandwidth=r * MB, rtt=rtt)
             for i, r in enumerate(rates)]
    py = simulate(StaticChunkingPolicy(chunk_size=chunk), specs, size, seed=0)
    jx = simulate_static([r * MB for r in rates], rtt, size, chunk)
    assert float(jx.total_time) == pytest.approx(py.total_time, rel=0.02)


def test_chunk_arrays_matches_chunk_params():
    """jax_alloc.chunk_sizes gives identical sizes whether the geometry
    arrives as a static ChunkParams or a traced ChunkArrays triple."""
    th = jnp.asarray([10 * MB, 0.0, 45 * MB, 3 * MB], jnp.float32)
    params = ChunkParams(4 * MB, 40 * MB)
    for remaining in (0.0, 1 * MB, 10 * GB):
        via_params = chunk_sizes(th, remaining, params)
        via_arrays = chunk_sizes(
            th, remaining, ChunkArrays.from_params(params), mode=params.mode)
        via_triple = chunk_sizes(th, remaining, params.as_triple())
        np.testing.assert_array_equal(np.asarray(via_params),
                                      np.asarray(via_arrays))
        np.testing.assert_array_equal(np.asarray(via_params),
                                      np.asarray(via_triple))


def test_sweep_scenarios_batch():
    """[S, N] scenario batch: row 0 of the fused lattice == the unbatched
    sweep of that scenario; argmins agree with autotune_batch."""
    scen = np.asarray([BW, [20.0 * MB] * 4, [5.0 * MB, 90.0 * MB,
                                             40.0 * MB, 10.0 * MB]])
    grid = default_grid()
    times = np.asarray(sweep_scenarios(scen, 0.03, 2 * GB, grid=grid))
    assert times.shape == (3, len(grid))
    single = autotune_chunk_params(BW, 0.03, 2 * GB, grid=grid)
    np.testing.assert_allclose(times[0], single.predicted_times, rtol=1e-6)

    results = autotune_batch(scen, 0.03, 2 * GB, grid=grid)
    assert len(results) == 3
    for row, res in zip(times, results):
        c, l = grid[int(np.argmin(row))]
        assert (res.params.initial_chunk, res.params.large_chunk) == (c, l)
        assert res.predicted_time == pytest.approx(float(row.min()), rel=1e-6)


def test_batch_per_scenario_file_sizes():
    """Per-scenario file sizes ride the same fused call."""
    scen = np.asarray([BW, BW])
    times = np.asarray(sweep_scenarios(
        scen, 0.03, np.asarray([1 * GB, 4 * GB]), grid=default_grid()[:4]))
    # same bandwidths, 4x the bytes -> strictly longer predicted times
    assert (times[1] > times[0]).all()


def test_client_retune_adopts_winner():
    """The data-plane retune hook feeds observed throughputs to the fused
    tuner and adopts the winning params for the next transfer."""
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    client = MDTPClient(replicas)
    with pytest.raises(RuntimeError):
        client.retune(2 * GB)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={}, requests_per_replica={},
        failed_replicas=[], refetched_ranges=0,
        observed_throughputs={"h0:1": 50.0 * MB, "h1:2": 10.0 * MB})
    res = client.retune(2 * GB)
    assert client._params_arg == res.params
    # the sweep models the client's pipelined data plane
    expect = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], 0.03, 2 * GB,
        pipeline_depth=client.pipeline_depth)
    assert res.params == expect.params
