"""Unit + property tests for the roofline walker (the measurement tool
every §Roofline/§Perf number flows through)."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.launch.hlo_analysis import analyze_hlo, _shape_bytes


def _mod(body: str, extra_comps: str = "") -> str:
    return f"""HloModule m

{extra_comps}
ENTRY %main (p0: f32[4,4]) -> f32[4,4] {{
  %p0 = f32[4,4]{{1,0}} parameter(0)
{body}
}}
"""


def test_dot_flops_and_bf16_charge():
    hlo = _mod("""  ROOT %d = f32[128,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
""").replace("%p0 = f32[4,4]{1,0} parameter(0)",
             "%a = f32[128,256]{1,0} parameter(0)\n"
             "  %b = f32[256,64]{1,0} parameter(1)")
    c = analyze_hlo(hlo)
    assert c.flops == 2 * 128 * 64 * 256
    # dot reads/writes charged at bf16 width (the MXU contract)
    expect = (128 * 256 + 256 * 64 + 128 * 64) * 2
    assert c.bytes_accessed == expect


def test_while_trip_count_multiplies():
    extra = """%body (t: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %t = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%t), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %y = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = (s32[], f32[64,64]{1,0}) tuple(%i2, %y)
}

%cond (t: (s32[], f32[64,64])) -> pred[] {
  %t = (s32[], f32[64,64]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
"""
    body = """  %zero = s32[] constant(0)
  %x0 = f32[64,64]{1,0} parameter(1)
  %init = (s32[], f32[64,64]{1,0}) tuple(%zero, %x0)
  %w = (s32[], f32[64,64]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
"""
    hlo = f"""HloModule m

{extra}
ENTRY %main (p0: f32[4,4], x0: f32[64,64]) -> f32[64,64] {{
  %p0 = f32[4,4]{{1,0}} parameter(0)
{body}}}
"""
    c = analyze_hlo(hlo)
    assert c.flops == 7 * 2 * 64 * 64 * 64  # trip count from %cond constant


def test_collective_bytes_and_types():
    hlo = _mod("""  %ar = f32[4,4]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  ROOT %c = f32[4,4]{1,0} copy(%ar)
""", extra_comps="""%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}
""")
    c = analyze_hlo(hlo)
    assert c.collective_count == 1
    assert c.collective_bytes == 4 * 4 * 4  # small f32: kept at f32
    assert "all-reduce" in c.collectives


def test_copy_reducer_allreduce_is_free():
    """psum_invariant (copy-reducer) moves no new data."""
    hlo = _mod("""  %ar = f32[4,4]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%cp
  ROOT %c = f32[4,4]{1,0} copy(%ar)
""", extra_comps="""%cp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %k = f32[] copy(%a)
}
""")
    c = analyze_hlo(hlo)
    assert c.collective_bytes == 0
    assert c.collective_count == 0


def test_large_f32_collective_charged_bf16():
    n = 2048 * 2048  # > 1M elems triggers the framework dtype invariant
    hlo = f"""HloModule m

%add (a: f32[], b: f32[]) -> f32[] {{
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}}

ENTRY %main (p0: f32[2048,2048]) -> f32[2048,2048] {{
  %p0 = f32[2048,2048]{{1,0}} parameter(0)
  %ar = f32[2048,2048]{{1,0}} all-reduce(%p0), replica_groups={{{{0,1}}}}, to_apply=%add
  ROOT %c = f32[2048,2048]{{1,0}} copy(%ar)
}}
"""
    c = analyze_hlo(hlo)
    assert c.collective_bytes == n * 2  # bf16-normalized


def test_f32c_marker_keeps_f32_charge():
    hlo = _mod("""  %e = f32[4,4]{1,0} exponential(%p0), metadata={op_name="jit(f)/f32c/exp"}
  ROOT %m = f32[4,4]{1,0} multiply(%e, %e), metadata={op_name="jit(f)/mul"}
""")
    c = analyze_hlo(hlo)
    # exp: read 64B (param, f32 unknown-origin) + write 64B (f32c)
    # mul: read resolved... exp marked f32c -> full width; mul unmarked
    # f32 compute -> result half width.
    exp_bytes = 64 + 64
    mul_bytes = 64 + 64 + 32  # two reads of marked exp + half-width write
    assert c.bytes_accessed == exp_bytes + mul_bytes


def test_dus_in_place_accounting():
    hlo = _mod("""  %big = f32[1024,1024]{1,0} parameter(1)
  %upd = f32[1,1024]{1,0} parameter(2)
  %i = s32[] constant(3)
  %z = s32[] constant(0)
  ROOT %dus = f32[1024,1024]{1,0} dynamic-update-slice(%big, %upd, %i, %z)
""")
    c = analyze_hlo(hlo)
    assert c.bytes_accessed == 2 * 1024 * 4  # 2x the slice, not the buffer


@given(st.integers(2, 64), st.integers(2, 64), st.integers(2, 64))
@settings(max_examples=25, deadline=None)
def test_dot_flops_formula_property(m, n, k):
    hlo = f"""HloModule m

ENTRY %main (a: bf16[{m},{k}], b: bf16[{k},{n}]) -> bf16[{m},{n}] {{
  %a = bf16[{m},{k}]{{1,0}} parameter(0)
  %b = bf16[{k},{n}]{{1,0}} parameter(1)
  ROOT %d = bf16[{m},{n}]{{1,0}} dot(%a, %b), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
}}
"""
    c = analyze_hlo(hlo)
    assert c.flops == 2 * m * n * k
    assert c.bytes_accessed == 2 * (m * k + k * n + m * n)


def test_shape_bytes_tuple():
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
