"""Pipelined data-plane model: ``SimConfig.pipeline_depth`` semantics on
the simulator cores and its threading through the tuner stack."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import autotune_chunk_params
from repro.core.chunking import ChunkParams
from repro.core.jax_alloc import ChunkArrays
from repro.core.jax_sim import SimConfig, simulate_scan_core, simulate_transfer

MB = 1024 * 1024
GB = 1024 * MB

BW = [40.0 * MB, 80.0 * MB, 160.0 * MB]
PARAMS = ChunkParams(initial_chunk=2 * MB, large_chunk=20 * MB)


def _time(depth, rtt=0.2, engine="round", file_size=2 * GB):
    return float(simulate_transfer(
        BW, rtt, file_size, PARAMS,
        config=SimConfig(pipeline_depth=depth), engine=engine,
    ).total_time)


def test_depth_one_is_the_legacy_model():
    """``pipeline_depth=1`` (the default) must reproduce the serial
    request-response model exactly — every chunk pays a full RTT."""
    for engine in ("event", "round"):
        t_default = float(simulate_transfer(
            BW, 0.2, 2 * GB, PARAMS, config=SimConfig(),
            engine=engine).total_time)
        assert _time(1, engine=engine) == t_default


@pytest.mark.parametrize("engine", ["event", "round"])
def test_pipelining_amortizes_rtt(engine):
    """On a high-RTT path, deeper pipelines strictly beat serial and the
    improvement is monotone: per-chunk latency max(0, rtt - (k-1)*body)
    can only shrink with k."""
    t1 = _time(1, engine=engine)
    t4 = _time(4, engine=engine)
    t8 = _time(8, engine=engine)
    assert t4 < t1
    assert t8 <= t4 + 1e-4
    # and bounded below by the pure wire time (bandwidth-limited floor)
    wire_floor = 2 * GB / sum(BW)
    assert t8 >= 0.5 * wire_floor


def test_deep_pipeline_approaches_zero_rtt_limit():
    """With the RTT fully hidden behind in-flight bodies, the transfer
    time approaches the (near-)zero-RTT serial time — the regime where
    the wire, not the request loop, is the bottleneck."""
    t_deep = _time(64, rtt=0.2)
    t_nortt = float(simulate_transfer(
        BW, 1e-4, 2 * GB, PARAMS, config=SimConfig(),
        engine="round").total_time)
    assert t_deep == pytest.approx(t_nortt, rel=0.05)


def test_first_chunk_still_pays_full_rtt():
    """The cold-pipe ramp is modeled: a one-chunk transfer cannot hide
    its RTT behind a pipeline that has nothing in flight yet."""
    small = 1 * MB          # a single chunk per server at most
    t1 = _time(1, rtt=0.5, file_size=small)
    t8 = _time(8, rtt=0.5, file_size=small)
    # every server's first (and only) chunk pays the RTT in both cases
    assert t8 == pytest.approx(t1, rel=1e-5)


def test_scan_core_depth_is_differentiable():
    """The smooth max(0, rtt - (k-1)*body) keeps reverse-mode gradients
    of the scan core finite and non-degenerate under pipelining."""
    cfg = SimConfig(max_rounds=256, exact_sizes=False, pipeline_depth=4)
    bw = jnp.asarray(BW, jnp.float32)
    rtt = jnp.full((3,), 0.2, jnp.float32)
    inf = jnp.full((3,), jnp.inf, jnp.float32)

    def loss(cl):
        chunk = ChunkArrays(cl[0], cl[1], jnp.float32(64 * 1024))
        return simulate_scan_core(
            bw, rtt, inf, bw, 0, chunk, jnp.float32(512 * MB),
            mode="proportional", config=cfg).total_time

    g = jax.grad(loss)(jnp.asarray([4.0 * MB, 40.0 * MB], jnp.float32))
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.any(np.asarray(g) != 0.0)


def test_autotune_pipeline_depth_shifts_the_tradeoff():
    """The fused sweep models request overlap: with pipelining, every
    grid point's predicted time is no worse than its serial prediction
    (RTT amortization only removes cost), so the adopted (C, L) stops
    over-paying for small chunks that pipelining makes cheap."""
    grid = [(1 * MB, 10 * MB), (2 * MB, 20 * MB), (4 * MB, 40 * MB),
            (8 * MB, 80 * MB), (16 * MB, 160 * MB)]
    serial = autotune_chunk_params(BW, 0.2, 4 * GB, grid=grid)
    piped = autotune_chunk_params(BW, 0.2, 4 * GB, grid=grid,
                                  pipeline_depth=4)
    t_serial = np.asarray(serial.predicted_times)
    t_piped = np.asarray(piped.predicted_times)
    assert np.all(t_piped <= t_serial + 1e-3)
    assert piped.predicted_time <= serial.predicted_time + 1e-3
    # the pipelined plan never needs a LARGER initial chunk than the
    # serial plan does to amortize the same latency
    assert piped.params.initial_chunk <= serial.params.initial_chunk


def test_online_tuners_thread_pipeline_depth():
    """GridTuner with pipeline_depth plans against the pipelined model —
    same result as calling the sweep directly with that depth."""
    from repro.core.online import GridTuner, Telemetry

    grid = [(1 * MB, 10 * MB), (4 * MB, 40 * MB), (16 * MB, 160 * MB)]
    tel = Telemetry(bandwidth=tuple(BW), rtt=(0.2, 0.2, 0.2),
                    remaining_bytes=float(4 * GB))
    tuned = GridTuner(grid=grid, pipeline_depth=4).update(tel)
    expect = autotune_chunk_params(
        list(BW), [0.2, 0.2, 0.2], 4 * GB, grid=grid, pipeline_depth=4)
    assert tuned == expect.params


# --------------------------------------------------------------------------
# per-chunk decode cost (the compressed dataplane's compute term)
# --------------------------------------------------------------------------

def _time_decode(decode_bw, engine="round", rtt=0.2, file_size=2 * GB):
    return float(simulate_transfer(
        BW, rtt, file_size, PARAMS,
        config=SimConfig(decode_bytes_per_s=decode_bw), engine=engine,
    ).total_time)


def test_zero_decode_rate_is_the_identity_model():
    """``decode_bytes_per_s=0.0`` (the default: identity dataplane) must
    reproduce the no-decode model exactly on both engines — the term is
    statically gated out, not just numerically negligible."""
    for engine in ("event", "round"):
        t_default = float(simulate_transfer(
            BW, 0.2, 2 * GB, PARAMS, config=SimConfig(),
            engine=engine).total_time)
        assert _time_decode(0.0, engine=engine) == t_default


@pytest.mark.parametrize("engine", ["event", "round"])
def test_decode_cost_is_monotone(engine):
    """A finite decode rate adds per-chunk compute time; a faster
    decoder costs strictly less than a slower one."""
    t_inf = _time_decode(0.0, engine=engine)
    t_fast = _time_decode(2000.0 * MB, engine=engine)
    t_slow = _time_decode(100.0 * MB, engine=engine)
    assert t_inf < t_fast < t_slow
    # the slow decoder is within the serial-decode upper bound:
    # wire time + all bytes through the decoder
    assert t_slow <= t_inf + 2 * GB / (100.0 * MB) + 1.0


def test_decode_cost_hides_behind_pipeline_like_body_time():
    """With pipelining, decode extends the per-chunk busy time and so
    helps hide the RTT — the combined model must not charge decode AND
    the full RTT when the pipe is deep."""
    deep = SimConfig(pipeline_depth=8, decode_bytes_per_s=200.0 * MB)
    serial = SimConfig(pipeline_depth=1, decode_bytes_per_s=200.0 * MB)
    t_deep = float(simulate_transfer(
        BW, 0.5, 2 * GB, PARAMS, config=deep, engine="round").total_time)
    t_serial = float(simulate_transfer(
        BW, 0.5, 2 * GB, PARAMS, config=serial, engine="round").total_time)
    assert t_deep < t_serial


def test_scan_core_decode_is_differentiable():
    """Gradients through the scan core stay finite and non-degenerate
    with the decode term on — the tuners' requirement."""
    cfg = SimConfig(max_rounds=256, exact_sizes=False,
                    decode_bytes_per_s=300.0 * MB)
    bw = jnp.asarray(BW, jnp.float32)
    rtt = jnp.full((3,), 0.2, jnp.float32)
    inf = jnp.full((3,), jnp.inf, jnp.float32)

    def loss(cl):
        chunk = ChunkArrays(cl[0], cl[1], jnp.float32(64 * 1024))
        return simulate_scan_core(
            bw, rtt, inf, bw, 0, chunk, jnp.float32(512 * MB),
            mode="proportional", config=cfg).total_time

    g = jax.grad(loss)(jnp.asarray([4.0 * MB, 40.0 * MB], jnp.float32))
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.any(np.asarray(g) != 0.0)


def test_autotune_threads_decode_rate():
    """The fused sweep charges decode cost: every grid point's predicted
    time with a finite decoder is >= its free-decode prediction, and the
    adopted plan accounts for the compute term."""
    grid = [(1 * MB, 10 * MB), (2 * MB, 20 * MB), (4 * MB, 40 * MB),
            (8 * MB, 80 * MB), (16 * MB, 160 * MB)]
    free = autotune_chunk_params(BW, 0.2, 4 * GB, grid=grid)
    taxed = autotune_chunk_params(BW, 0.2, 4 * GB, grid=grid,
                                  decode_bytes_per_s=150.0 * MB)
    t_free = np.asarray(free.predicted_times)
    t_taxed = np.asarray(taxed.predicted_times)
    assert np.all(t_taxed >= t_free - 1e-3)
    assert taxed.predicted_time > free.predicted_time


def test_online_tuners_thread_decode_rate():
    """Each online tuner plans against the decode-taxed model — the
    GridTuner matches the direct sweep, and the gradient/bandit tuners
    accept and carry the knob."""
    from repro.core.online import BanditTuner, GridTuner, Telemetry

    grid = [(1 * MB, 10 * MB), (4 * MB, 40 * MB), (16 * MB, 160 * MB)]
    tel = Telemetry(bandwidth=tuple(BW), rtt=(0.2, 0.2, 0.2),
                    remaining_bytes=float(4 * GB))
    tuned = GridTuner(grid=grid, decode_bytes_per_s=150.0 * MB).update(tel)
    expect = autotune_chunk_params(
        list(BW), [0.2, 0.2, 0.2], 4 * GB, grid=grid,
        decode_bytes_per_s=150.0 * MB)
    assert tuned == expect.params
    # the bandit seeds its arms from the decode-taxed sweep without error
    bt = BanditTuner(grid=grid, decode_bytes_per_s=150.0 * MB)
    assert bt.update(tel) is not None
