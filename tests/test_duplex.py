"""Duplex transport lanes: the independent writer coroutine's send
pipelining, its failure semantics (per-lane conservation, no deadlock on
a severed socket), and half-duplex parity."""

import asyncio
import hashlib
import threading

import numpy as np

from repro.core.chunking import ChunkParams
from repro.transfer import (MDTPClient, RangeServer, Replica, Throttle,
                            fetch_blob)
from repro.transfer.transport import _Conn

KB = 1024
MB = 1024 * 1024

_LANE_ERRORS = (ConnectionError, OSError, asyncio.IncompleteReadError)


def _blob(n: int, seed: int = 0) -> bytes:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()


def _sha(b) -> str:
    return hashlib.sha256(b).hexdigest()


def test_duplex_writer_pipelines_while_body_in_flight():
    """Two lanes enqueued together: the writer puts the second request
    on the wire while the first body is still streaming, so the second
    reply is judged pipelined (its elapsed excludes request RTT)."""
    blob = _blob(2 * MB)
    s = RangeServer(
        throttle=Throttle(bytes_per_s=8 * MB, deterministic=True)).start()
    s.add_blob("/data", blob)

    async def run():
        conn = _Conn(Replica("127.0.0.1", s.port, "/data"))
        try:
            lane1 = asyncio.ensure_future(conn.fetch_range(0, MB - 1))
            lane2 = asyncio.ensure_future(conn.fetch_range(MB, 2 * MB - 1))
            r1, r2 = await asyncio.gather(lane1, lane2)
            assert bytes(r1.data) == blob[:MB]
            assert bytes(r2.data) == blob[MB:]
            assert r1.rtt_included          # first lane paid the RTT
            assert not r2.rtt_included      # second rode the full pipe
        finally:
            await conn.close()

    try:
        asyncio.run(run())
    finally:
        s.stop()


def test_conn_death_fails_every_queued_lane_exactly_once():
    """Sever the socket with lanes deep in the write queue: every lane
    resolves — success or exactly one ConnectionError — and none hangs.
    This is the conservation contract the client's re-pool rides on."""
    blob = _blob(8 * MB, seed=1)
    s = RangeServer(
        throttle=Throttle(bytes_per_s=2 * MB, deterministic=True)).start()
    s.add_blob("/data", blob)

    async def run():
        conn = _Conn(Replica("127.0.0.1", s.port, "/data"))
        try:
            lanes = [asyncio.ensure_future(
                conn.fetch_range(i * MB, (i + 1) * MB - 1))
                for i in range(8)]
            await asyncio.sleep(0.2)        # queue fills behind body 1
            s.kill_connections()
            done = await asyncio.wait_for(
                asyncio.gather(*lanes, return_exceptions=True), timeout=30)
            assert len(done) == 8           # every lane resolved
            errs = [r for r in done if isinstance(r, BaseException)]
            assert errs                     # the kill landed mid-queue
            assert all(isinstance(e, _LANE_ERRORS) for e in errs)
            ok = [r for r in done if not isinstance(r, BaseException)]
            for i, r in enumerate(ok):
                assert bytes(r.data) == blob[i * MB:(i + 1) * MB]
        finally:
            await conn.close()

    try:
        asyncio.run(run())
    finally:
        s.stop()


def test_abort_does_not_deadlock_queued_writer():
    """A hedge winner severs the loser with ``abort()``: the loser's
    queued lanes must all fail promptly — the writer coroutine may not
    deadlock holding un-failed futures."""
    blob = _blob(4 * MB, seed=2)
    s = RangeServer(
        throttle=Throttle(bytes_per_s=2 * MB, deterministic=True)).start()
    s.add_blob("/data", blob)

    async def run():
        conn = _Conn(Replica("127.0.0.1", s.port, "/data"))
        try:
            lanes = [asyncio.ensure_future(
                conn.fetch_range(i * MB, (i + 1) * MB - 1))
                for i in range(4)]
            await asyncio.sleep(0.2)        # body 1 mid-flight, 3 queued
            conn.abort()
            done = await asyncio.wait_for(
                asyncio.gather(*lanes, return_exceptions=True), timeout=10)
            errs = [r for r in done if isinstance(r, BaseException)]
            assert len(errs) >= 3           # queued lanes all failed
            assert all(isinstance(e, _LANE_ERRORS) for e in errs)
            assert conn.broken
        finally:
            await conn.close()

    try:
        asyncio.run(run())
    finally:
        s.stop()


def test_client_repools_duplex_queue_on_mirror_death():
    """End to end: a mirror dies with pipelined requests queued in the
    duplex writer; every owed range re-pools exactly once and the blob
    hash still matches (byte conservation across the re-pool)."""
    blob = _blob(8 * MB, seed=3) * 2
    victim = RangeServer(throttle=Throttle(bytes_per_s=4 * MB,
                                           deterministic=True)).start()
    victim.add_blob("/data", blob)
    survivor = RangeServer(throttle=Throttle(bytes_per_s=40 * MB,
                                             deterministic=True)).start()
    survivor.add_blob("/data", blob)
    try:
        replicas = [Replica("127.0.0.1", victim.port, "/data"),
                    Replica("127.0.0.1", survivor.port, "/data")]

        def kill():
            victim.kill_connections()
            victim.stop()

        threading.Timer(0.15, kill).start()
        data, report = fetch_blob(
            replicas, len(blob),
            params=ChunkParams(initial_chunk=256 * KB, large_chunk=MB),
            max_failures=50, pipeline_depth=6, retry_backoff_cap=0.2)
        assert _sha(data) == _sha(blob)
        assert sum(report.bytes_per_replica.values()) == len(blob)
    finally:
        survivor.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_half_duplex_fallback_parity():
    """``duplex=False`` (the benchmark baseline) still moves bytes
    correctly through the legacy inline-send path."""
    blob = _blob(6 * MB, seed=4)
    servers = []
    for bw in (30 * MB, 60 * MB):
        s = RangeServer(
            throttle=Throttle(bytes_per_s=bw, deterministic=True)).start()
        s.add_blob("/data", blob)
        servers.append(s)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        client = MDTPClient(replicas, duplex=False,
                            params=ChunkParams(initial_chunk=256 * KB,
                                               large_chunk=MB))
        data, report = asyncio.run(client.fetch(len(blob)))
        assert _sha(data) == _sha(blob)
        assert sum(report.bytes_per_replica.values()) == len(blob)
    finally:
        for s in servers:
            s.stop()
