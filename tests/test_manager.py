"""Fleet-shared multi-transfer scheduling (``repro.transfer.manager``).

Properties under test:

* **bytes conservation** — K concurrent managed transfers each deliver
  their exact blob (sha-verified) and each transfer's per-replica byte
  counts sum to its size;
* **per-replica in-flight caps** — across ALL transfers, no mirror ever
  serves more than ``max_inflight_per_replica`` simultaneous requests
  (server-side high-water witness), while an uncapped control run does
  overlap;
* **staggered-arrival fairness** — a transfer arriving mid-flight is not
  starved: it completes and draws bytes from every live mirror;
* **warm-start persistence** — geometry adopted during one transfer
  seeds the next transfer's first round, and a shared tuner's state
  (bandit arms) survives across transfers;
* the fleet model's residual-capacity arithmetic and telemetry
  substitution (pure units, no sockets).
"""

import asyncio
import dataclasses
import hashlib

import numpy as np
import pytest

from repro.core.chunking import ChunkParams
from repro.transfer import (
    RangeServer,
    Replica,
    Throttle,
    TransferJob,
    TransferManager,
)
from repro.transfer.manager import FleetModel

MB = 1024 * 1024


def _mirrors(blobs: dict, rates, deterministic=True):
    servers = []
    for r in rates:
        s = RangeServer(throttle=Throttle(
            bytes_per_s=r, deterministic=deterministic)).start()
        for path, blob in blobs.items():
            s.add_blob(path, blob)
        servers.append(s)
    return servers


def _blobs(k, size, seed=0):
    rng = np.random.default_rng(seed)
    return {f"/b{j}": rng.integers(0, 256, size=size, dtype=np.uint8)
            .tobytes() for j in range(k)}


# -- fleet model units ------------------------------------------------------

def test_allocation_view_residual_and_floor():
    fleet = FleetModel()
    reps = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    fleet.register("t1")
    fleet.register("t2")
    # t2 consumes 60 MB/s of h0's capacity; no observations for h1
    for _ in range(60):
        fleet.observe_chunk("t1", "h0:1", 40 * MB, 1.0)
        fleet.observe_chunk("t2", "h0:1", 60 * MB, 1.0)
    view = fleet.allocation_view("t1", reps, [40.0 * MB, 25.0 * MB])
    # residual for t1 on h0 ~ capacity (100) - foreign (60) = 40 MB/s
    assert view[0] == pytest.approx(40 * MB, rel=0.05)
    # h1 unknown to the fleet: t1's own estimate passes through
    assert view[1] == 25.0 * MB
    # unprobed replica stays <= 0 so the client still sends its probe
    assert fleet.allocation_view("t1", reps, [0.0, 0.0]) == [0.0, 0.0]
    # t2 finishing returns its share to the residual
    fleet.forget("t2")
    view = fleet.allocation_view("t1", reps, [40.0 * MB, 25.0 * MB])
    assert view[0] == pytest.approx(100 * MB, rel=0.05)


def test_allocation_view_floor_prevents_starvation():
    fleet = FleetModel()
    reps = [Replica("h0", 1, "/b")]
    fleet.register("t1")
    fleet.register("t2")
    # t2 hogs essentially the whole mirror
    for _ in range(60):
        fleet.observe_chunk("t2", "h0:1", 100 * MB, 1.0)
        fleet.observe_chunk("t1", "h0:1", 1 * MB, 1.0)
    view = fleet.allocation_view("t1", reps, [1.0 * MB])
    # floored at capacity / (2 * n_active), never the raw <= 0 residual
    assert view[0] >= 100 * MB / (2 * 2) * 0.8


def test_fleet_telemetry_substitutes_residual_and_rtt():
    @dataclasses.dataclass(frozen=True)
    class Tel:  # shape-compatible stand-in; keeps jax out of this test
        bandwidth: tuple
        rtt: tuple
        remaining_bytes: float

    fleet = FleetModel()
    reps = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    fleet.register("t1")
    fleet.observe_rtt("h0:1", 0.25)
    for _ in range(30):
        fleet.observe_chunk("t1", "h0:1", 50 * MB, 1.0)
    out = fleet.fleet_telemetry(
        "t1", reps, Tel(bandwidth=(10.0 * MB, 20.0 * MB),
                        rtt=(0.03, 0.04), remaining_bytes=5.0))
    assert out.bandwidth[0] > 10.0 * MB          # residual view, not local
    assert out.bandwidth[1] == 20.0 * MB         # unknown mirror: local
    assert out.rtt[0] == pytest.approx(0.25, rel=0.2)
    assert out.rtt[1] == 0.04
    assert out.remaining_bytes == 5.0            # everything else intact


def test_fleet_model_rejects_bad_cap():
    with pytest.raises(ValueError):
        FleetModel(max_inflight_per_replica=0)


# -- bytes conservation under K concurrent transfers ------------------------

def test_concurrent_transfers_bytes_conservation():
    k = 3
    blobs = _blobs(k, 2 * MB)
    servers = _mirrors(blobs, [30 * MB, 90 * MB])
    try:
        reps = [Replica("127.0.0.1", s.port, "/b0") for s in servers]
        mgr = TransferManager(
            reps, params=ChunkParams(128 * 1024, 512 * 1024))
        out = mgr.run([TransferJob(len(blobs[f"/b{j}"]), path=f"/b{j}")
                       for j in range(k)])
        assert len(out) == k
        for j, (buf, report) in enumerate(out):
            blob = blobs[f"/b{j}"]
            assert hashlib.sha256(bytes(buf)).digest() == \
                hashlib.sha256(blob).digest()
            # conservation: per-replica contributions sum to the size
            assert sum(report.bytes_per_replica.values()) == len(blob)
            assert report.failed_replicas == []
        assert len(mgr.reports) == k
        # the fleet model saw every mirror
        snap = mgr.snapshot()
        assert set(snap) == {r.name for r in reps}
        assert all(v["capacity"] > 0 for v in snap.values())
    finally:
        for s in servers:
            s.stop()


# -- per-replica in-flight caps ---------------------------------------------

def test_per_replica_inflight_cap_enforced():
    k = 3
    blobs = _blobs(k, 2 * MB, seed=1)
    servers = _mirrors(blobs, [25 * MB, 50 * MB])
    try:
        reps = [Replica("127.0.0.1", s.port, "/b0") for s in servers]
        mgr = TransferManager(
            reps, params=ChunkParams(128 * 1024, 512 * 1024),
            max_inflight_per_replica=1)
        out = mgr.run([TransferJob(len(blobs[f"/b{j}"]), path=f"/b{j}")
                       for j in range(k)])
        for j, (buf, _) in enumerate(out):
            assert bytes(buf) == blobs[f"/b{j}"]
        # the cap held on every mirror, across ALL transfers at once
        for s in servers:
            assert s.peak_concurrent_requests <= 1
    finally:
        for s in servers:
            s.stop()


def test_uncapped_control_overlaps_requests():
    """The witness gauge actually measures overlap: with a generous cap,
    K concurrent transfers do stack requests on the same mirror."""
    k = 3
    blobs = _blobs(k, 2 * MB, seed=2)
    servers = _mirrors(blobs, [25 * MB])
    try:
        reps = [Replica("127.0.0.1", servers[0].port, "/b0")]
        mgr = TransferManager(
            reps, params=ChunkParams(128 * 1024, 512 * 1024),
            max_inflight_per_replica=8)
        mgr.run([TransferJob(len(blobs[f"/b{j}"]), path=f"/b{j}")
                 for j in range(k)])
        assert servers[0].peak_concurrent_requests >= 2
    finally:
        for s in servers:
            s.stop()


# -- staggered arrivals / fairness ------------------------------------------

def test_staggered_arrival_not_starved():
    blobs = _blobs(2, 3 * MB, seed=3)
    servers = _mirrors(blobs, [40 * MB, 80 * MB])
    try:
        reps = [Replica("127.0.0.1", s.port, "/b0") for s in servers]
        mgr = TransferManager(
            reps, params=ChunkParams(128 * 1024, 512 * 1024))
        out = mgr.run([
            TransferJob(len(blobs["/b0"]), path="/b0"),
            TransferJob(len(blobs["/b1"]), path="/b1", start_delay=0.02),
        ])
        for j, (buf, report) in enumerate(out):
            assert bytes(buf) == blobs[f"/b{j}"]
            # fairness: every live mirror served this transfer — the
            # late arrival was packed into residual capacity, not starved
            # behind the incumbent
            assert all(v > 0 for v in report.bytes_per_replica.values())
            assert report.failed_replicas == []
    finally:
        for s in servers:
            s.stop()


# -- warm start / tuner persistence ------------------------------------------

class _AdoptOnce:
    """Scripted tuner: adopts a fixed geometry on every update (kept off
    the ``params`` attribute so the warm-start must flow through the
    manager's adopted-params slot, not the tuner fallback)."""

    def __init__(self, target):
        self.target = target
        self.updates = 0

    def update(self, telemetry):
        self.updates += 1
        return self.target


def test_adopted_params_warm_start_next_transfer():
    blobs = _blobs(2, 3 * MB, seed=4)
    servers = _mirrors(blobs, [60 * MB, 60 * MB])
    try:
        reps = [Replica("127.0.0.1", s.port, "/b0") for s in servers]
        learned = ChunkParams(initial_chunk=192 * 1024,
                              large_chunk=768 * 1024)
        mgr = TransferManager(reps,
                              params=ChunkParams(128 * 1024, 512 * 1024),
                              tuner=_AdoptOnce(learned))
        (buf, report), = mgr.run([TransferJob(
            len(blobs["/b0"]), path="/b0",
            tune_interval_bytes=256 * 1024)])
        assert bytes(buf) == blobs["/b0"]
        assert report.retunes >= 1
        # adoption persisted onto the manager...
        assert mgr.params == learned

        # ...and the SECOND transfer's client starts from it (first-round
        # geometry is the learned one, not the size-derived default)
        async def second():
            async with mgr.session(path="/b1") as client:
                assert client._params_arg == learned
                buf2, _ = await client.fetch(len(blobs["/b1"]))
                return buf2

        buf2 = asyncio.run(second())
        assert bytes(buf2) == blobs["/b1"]
    finally:
        for s in servers:
            s.stop()


def test_non_adopting_transfer_does_not_clobber_learned_params():
    """Regression: a transfer that merely rode its construction-time
    warm params must not overwrite geometry a concurrent peer ADOPTED —
    persistence is adoption-gated, not last-session-exit-wins."""
    p0 = ChunkParams(initial_chunk=128 * 1024, large_chunk=512 * 1024)
    p1 = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
    reps = [Replica("h0", 1, "/b")]
    mgr = TransferManager(reps, params=p0)

    async def scenario():
        async with mgr.session() as slow:       # warm-started on p0
            async with mgr.session() as fast:
                fast.adopt_params(p1)           # peer learns p1...
            assert mgr.params == p1             # ...and persists it
            assert slow._params_arg == p0       # never adopted anything
        # slow's exit must NOT reset the manager to stale p0
        assert mgr.params == p1

    asyncio.run(scenario())


def test_bandit_state_persists_across_transfers():
    """A shared BanditTuner keeps its arms (and their discounted reward
    statistics) across managed transfers — the second transfer explores
    from learned state instead of re-seeding."""
    jax = pytest.importorskip("jax")  # noqa: F841  (bandit seeding sweeps)
    from repro.core.online import BanditTuner

    blobs = _blobs(2, 4 * MB, seed=5)
    servers = _mirrors(blobs, [40 * MB, 80 * MB])
    try:
        reps = [Replica("127.0.0.1", s.port, "/b0") for s in servers]
        grid = [(128 * 1024, 512 * 1024), (256 * 1024, MB),
                (512 * 1024, 2 * MB)]
        tuner = BanditTuner(n_arms=2, grid=grid)
        mgr = TransferManager(reps, tuner=tuner,
                              params=ChunkParams(128 * 1024, 512 * 1024))
        mgr.run([TransferJob(len(blobs["/b0"]), path="/b0",
                             tune_interval_bytes=512 * 1024)])
        assert tuner.updates >= 1
        assert tuner.arms                          # seeded during t1
        updates_after_first = tuner.updates
        mgr.run([TransferJob(len(blobs["/b1"]), path="/b1",
                             tune_interval_bytes=512 * 1024)])
        # same tuner object kept accumulating across transfers
        assert tuner.arms
        assert tuner.updates >= updates_after_first + 1
    finally:
        for s in servers:
            s.stop()


# -- contention sweep (simulator mirror) -------------------------------------

def test_contention_sweep_ladder():
    pytest.importorskip("jax")
    from repro.core.autotune import autotune_chunk_params, contention_sweep

    bw = [12.0 * MB, 70.0 * MB]
    ladder = contention_sweep(bw, 0.2, 512 * MB, max_transfers=3)
    assert sorted(ladder) == [1, 2, 3]
    # k=1 is exactly the solo fused tune
    solo = autotune_chunk_params(bw, 0.2, 512 * MB)
    assert ladder[1].params == solo.params
    assert ladder[1].predicted_time == pytest.approx(solo.predicted_time)
    # contention can only slow the predicted transfer down
    assert ladder[2].predicted_time > ladder[1].predicted_time
    assert ladder[3].predicted_time > ladder[2].predicted_time
    with pytest.raises(ValueError):
        contention_sweep(bw, 0.2, 512 * MB, ks=[0, 1])


def test_plan_contention_ladder_on_manager():
    pytest.importorskip("jax")

    reps = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    mgr = TransferManager(reps)
    # nothing observed yet and no explicit bandwidth: nothing to plan from
    with pytest.raises(ValueError):
        mgr.plan_contention(256 * MB, max_transfers=2)
    ladder = mgr.plan_contention(
        256 * MB, max_transfers=2, bandwidth=[12.0 * MB, 70.0 * MB],
        rtt=[0.2, 0.2])
    assert set(ladder) == {1, 2}
    assert mgr.contention_ladder == ladder
    assert all(isinstance(p, ChunkParams) for p in ladder.values())
    # the ladder seeds a new transfer's geometry for the current k
    assert mgr._warm_params(n_active=2) == ladder[2]
    assert mgr._warm_params(n_active=1) == ladder[1]


def test_contention_scenarios_helpers():
    from repro.core.scenarios import (
        ContentionTrace,
        contention_matrix,
        contention_traces,
        paper_baseline,
        with_fair_share,
    )

    servers = paper_baseline()
    halved = with_fair_share(servers, 2)
    assert [s.bandwidth for s in halved] == \
        [s.bandwidth / 2 for s in servers]
    assert [s.rtt for s in halved] == [s.rtt for s in servers]
    mat = contention_matrix(servers, [1, 2, 4])
    assert len(mat) == 3 and len(mat[0]) == len(servers)
    assert mat[2][0] == servers[0].bandwidth / 4
    traces = contention_traces()
    assert {t.name for t in traces} == \
        {"simultaneous", "staggered", "bottleneck"}
    for t in traces:
        assert len(t.sizes) == len(t.arrivals)
    with pytest.raises(ValueError):
        ContentionTrace("bad", tuple(servers), sizes=(1, 2),
                        arrivals=(0.0,))
