"""Online tuning subsystem: MC-gradient tuner, bandit, client/restore wiring.

Contracts under test (see ``repro.core.online``):

* ``tune_chunk_params_mcgrad`` shares the grad tuner's never-worse-than-
  init guarantee on the exact metric, and its compiled value-and-grad is
  cached across file sizes (an online tuner re-plans every wave without
  recompiling the scan core);
* ``BanditTuner`` seeds its arms from the fused grid winner, explores
  every arm, exploits the measured-reward best, and resets confidence on
  bandwidth/RTT drift or replica death;
* ``MDTPClient.fetch(tuner=...)`` feeds live telemetry mid-transfer and
  adopts returned params (``report.retunes``); ``restore_checkpoint``
  re-tunes between waves and the wave/offset plumbing delivers exact
  bytes.
"""

import asyncio
import hashlib

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.autotune import autotune_chunk_params  # noqa: E402
from repro.core.chunking import ChunkParams  # noqa: E402
from repro.core.online import (  # noqa: E402
    BanditTuner,
    GridTuner,
    MCGradTuner,
    Telemetry,
    _mc_value_and_grad,
    rtt_corrected_bandwidth,
    tune_chunk_params_mcgrad,
)
from repro.transfer import RangeServer, Replica, Throttle  # noqa: E402
from repro.transfer.client import MDTPClient  # noqa: E402

MB = 1024 * 1024
GB = 1024 * MB

BW = [50.0 * MB, 30.0 * MB, 10.0 * MB, 80.0 * MB]


def _tel(bw, rtt=0.03, remaining=512 * MB, throughput=0.0, elapsed=0.0):
    n = len(bw)
    rtt = (rtt,) * n if isinstance(rtt, float) else tuple(rtt)
    return Telemetry(bandwidth=tuple(bw), rtt=rtt,
                     remaining_bytes=float(remaining),
                     measured_throughput=float(throughput), elapsed=elapsed)


# -- Telemetry / estimator-correction helpers -------------------------------

def test_telemetry_live_filters_dead_and_fills_rtt():
    t = _tel([50.0 * MB, 0.0, 10.0 * MB], rtt=(0.25, 0.0, 0.0))
    bw, rtts = t.live(default_rtt=0.07)
    assert bw == [50.0 * MB, 10.0 * MB]
    assert rtts == [0.25, 0.07]          # dead slot dropped, gap filled


def test_rtt_corrected_bandwidth_inverts_estimator_bias():
    """est = s/(rtt + s/bw)  ==>  correction recovers bw exactly."""
    for bw, rtt, s in [(70 * MB, 0.5, 40 * MB), (12 * MB, 0.03, 2 * MB)]:
        est = s / (rtt + s / bw)
        assert est < bw                                  # bias is real
        assert rtt_corrected_bandwidth(est, rtt, s) == pytest.approx(
            bw, rel=1e-6)
    # impossible corrections pass the reading through unchanged
    assert rtt_corrected_bandwidth(5.0, 0.0, 1 * MB) == 5.0
    assert rtt_corrected_bandwidth(5.0, 0.5, 0.0) == 5.0
    assert rtt_corrected_bandwidth(0.0, 0.5, 1 * MB) == 0.0
    # implied non-positive wire time (reading faster than RTT allows)
    assert rtt_corrected_bandwidth(10 * MB, 1.0, 1 * MB) == 10 * MB


def test_telemetry_from_report_passes_wire_rates_through():
    """Regression: ``observed_throughputs`` are wire rates (the client
    strips the per-request RTT bias at the observation point), so the
    wave-boundary ``Telemetry.from_report`` path must NOT correct them a
    second time — only zero failed slots and carry the measured RTTs."""
    from repro.transfer.client import Replica, TransferReport

    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b"),
                Replica("h2", 3, "/b")]
    wire, rtt = 70.0 * MB, 0.5
    report = TransferReport(
        total_bytes=1, elapsed=2.0,
        bytes_per_replica={"h0:1": 160 * MB, "h1:2": 8 * MB,
                           "h2:3": MB},
        requests_per_replica={"h0:1": 4, "h1:2": 2, "h2:3": 1},
        failed_replicas=["h2:3"], refetched_ranges=0,
        observed_throughputs={"h0:1": wire, "h1:2": 20.0 * MB,
                              "h2:3": 5.0 * MB},
        observed_rtts={"h0:1": rtt, "h1:2": 0.0, "h2:3": 0.02})
    tel = Telemetry.from_report(report, replicas, remaining_bytes=64 * MB)
    assert tel.bandwidth[0] == wire            # as-is (already de-biased;
    # a second rtt_corrected_bandwidth pass would inflate it past wire)
    assert tel.bandwidth[1] == 20.0 * MB
    assert tel.bandwidth[2] == 0.0             # failed slot preserved
    assert tel.rtt == (rtt, 0.0, 0.02)
    assert tel.remaining_bytes == 64 * MB


# -- MC-gradient tuner ------------------------------------------------------

def test_mcgrad_never_worse_than_grid_init():
    grid = [(2 * MB, 20 * MB), (4 * MB, 40 * MB), (8 * MB, 80 * MB)]
    seed = autotune_chunk_params(BW, 0.03, 512 * MB, grid=grid)
    res = tune_chunk_params_mcgrad(
        BW, 0.03, 512 * MB,
        init=(seed.params.initial_chunk, seed.params.large_chunk),
        steps=6, n_seeds=2, max_rounds=256)
    assert res.steps == 6
    assert all(np.isfinite(t) for t in res.loss_history)
    assert np.all(np.isfinite(res.final_grad))
    # exact-metric guarantee: adopted params no slower than the init
    from repro.core.jax_sim import SimConfig, simulate_transfer
    t_init = float(simulate_transfer(
        BW, 0.03, 512 * MB, seed.params, config=SimConfig(),
        engine="round").total_time)
    assert res.predicted_time <= t_init + 1e-6


def test_mcgrad_compiled_loss_cached_across_file_sizes():
    """File size and z-floors are traced args of the cached value-and-grad:
    re-planning for a different remaining byte count must reuse the same
    compiled executable (same lru entry, no scan-core retrace)."""
    _mc_value_and_grad.cache_clear()
    tune_chunk_params_mcgrad(BW, 0.03, 256 * MB, init=(4 * MB, 40 * MB),
                             steps=2, n_seeds=2, max_rounds=128)
    assert _mc_value_and_grad.cache_info().misses == 1
    tune_chunk_params_mcgrad(BW, 0.03, 200 * MB, init=(4 * MB, 40 * MB),
                             steps=2, n_seeds=2, max_rounds=128)
    info = _mc_value_and_grad.cache_info()
    assert info.misses == 1 and info.hits >= 1


def test_mcgrad_tuner_update_adopts_and_warm_starts():
    tun = MCGradTuner(steps=4, n_seeds=2, max_rounds=128)
    assert tun.update(_tel([0.0, 0.0])) is None           # nothing live
    p = tun.update(_tel(BW, remaining=256 * MB))
    assert isinstance(p, ChunkParams)
    assert tun.params == p and tun.updates == 1
    p2 = tun.update(_tel(BW, remaining=200 * MB))
    assert isinstance(p2, ChunkParams)                    # warm-started


# -- bandit -----------------------------------------------------------------

def test_bandit_seeds_arms_from_grid_winner():
    grid = [(2 * MB, 20 * MB), (4 * MB, 40 * MB), (8 * MB, 80 * MB),
            (16 * MB, 160 * MB)]
    tun = BanditTuner(n_arms=3, grid=grid)
    p = tun.update(_tel(BW))
    expect = autotune_chunk_params(BW, [0.03] * 4, 512 * MB, grid=grid)
    assert p == expect.params                 # arm 0 == the grid winner
    assert len(tun.arms) == 3
    # arms are distinct grid points ranked by predicted time
    assert len({(a.params.initial_chunk, a.params.large_chunk)
                for a in tun.arms}) == 3


def test_bandit_explores_then_exploits_measured_best():
    grid = [(2 * MB, 20 * MB), (4 * MB, 40 * MB), (8 * MB, 80 * MB)]
    tun = BanditTuner(n_arms=3, grid=grid, gamma=1.0, explore=0.05)
    tun.update(_tel(BW))                       # seed; plays arm 0
    # reward schedule: arm 0 mediocre, arm 1 great, arm 2 poor
    rewards = {0: 0.5, 1: 0.95, 2: 0.1}
    played = []
    for _ in range(8):
        idx = tun._current
        played.append(idx)
        tun.update(_tel(BW, throughput=rewards[idx] * sum(BW)))
    assert set(played[:3]) == {0, 1, 2}        # every arm tried once
    assert played[-1] == 1                     # converges on measured best
    assert tun.params == tun.arms[1].params


def test_bandit_drift_resets_on_throttle_death_and_latency():
    for mutate in (
        lambda bw, rtt: (tuple(b * 0.2 if i == 3 else b                # throttle
                               for i, b in enumerate(bw)), rtt),
        lambda bw, rtt: (tuple(0.0 if i == 3 else b                    # death
                               for i, b in enumerate(bw)), rtt),
        lambda bw, rtt: (bw, tuple(r + 0.5 for r in rtt)),             # latency
    ):
        tun = BanditTuner(n_arms=2)
        tun.update(_tel(BW))
        assert tun.drift_resets == 0
        bw2, rtt2 = mutate(tuple(BW), (0.03,) * 4)
        p = tun.update(Telemetry(bw2, rtt2, 256 * MB,
                                 measured_throughput=50 * MB))
        assert tun.drift_resets == 1
        assert p is not None
        # confidence was zeroed: every arm unplayed again
        assert all(a.n == 0.0 for a in tun.arms)


def test_bandit_steady_fleet_does_not_reset():
    tun = BanditTuner(n_arms=2, drift_threshold=0.6)
    tun.update(_tel(BW))
    # 20% wobble is below the 60% drift threshold
    wobble = tuple(b * 1.2 for b in BW)
    tun.update(_tel(wobble, throughput=60 * MB))
    assert tun.drift_resets == 0


def test_grid_tuner_tracks_fused_sweep():
    tun = GridTuner()
    p = tun.update(_tel(BW, remaining=256 * MB))
    expect = autotune_chunk_params(BW, [0.03] * 4, 256 * MB)
    assert p == expect.params
    assert tun.update(_tel([0.0] * 4)) is None


# -- client wiring ----------------------------------------------------------

class _ScriptedTuner:
    """Deterministic stand-in: records telemetry, returns a fixed param."""

    def __init__(self, params):
        self.params = params
        self.seen = []

    def update(self, t):
        self.seen.append(t)
        return self.params


def _mirrors(blob, rates):
    servers = []
    for r in rates:
        s = RangeServer(throttle=Throttle(bytes_per_s=r)).start()
        s.add_blob("/data", blob)
        servers.append(s)
    return servers


def test_fetch_tuner_hook_adopts_params_and_reports_retunes():
    rng = np.random.default_rng(1)
    blob = rng.integers(0, 256, size=6 * MB, dtype=np.uint8).tobytes()
    servers = _mirrors(blob, [40 * MB, 80 * MB])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        adopted = ChunkParams(initial_chunk=128 * 1024, large_chunk=512 * 1024)
        tuner = _ScriptedTuner(adopted)
        client = MDTPClient(replicas,
                            params=ChunkParams(256 * 1024, MB))
        buf, report = asyncio.run(client.fetch(
            len(blob), tuner=tuner, tune_interval_bytes=MB))
        assert hashlib.sha256(bytes(buf)).digest() == \
            hashlib.sha256(blob).digest()
        assert report.retunes >= 1
        assert len(tuner.seen) >= 1
        tel = tuner.seen[0]
        # live telemetry: positional vectors over the full replica set,
        # measured window throughput, true remaining count
        assert len(tel.bandwidth) == 2 and len(tel.rtt) == 2
        assert any(b > 0 for b in tel.bandwidth)
        assert tel.measured_throughput > 0
        assert 0 <= tel.remaining_bytes < len(blob)
        # adoption persists for the next transfer
        assert client._params_arg == adopted
    finally:
        for s in servers:
            s.stop()


def test_fetch_tuner_without_adoption_leaves_params_unpinned():
    """A tuner that declines every update (returns None) must not pin this
    transfer's size-derived default params onto subsequent transfers."""
    rng = np.random.default_rng(4)
    blob = rng.integers(0, 256, size=4 * MB, dtype=np.uint8).tobytes()
    servers = _mirrors(blob, [80 * MB])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]

        class DeclineTuner:
            def update(self, t):
                return None

        client = MDTPClient(replicas, tuner=DeclineTuner())
        buf, report = asyncio.run(client.fetch(
            len(blob), tune_interval_bytes=MB))
        assert bytes(buf) == blob
        assert report.retunes == 0
        assert client._params_arg is None
    finally:
        for s in servers:
            s.stop()


def test_fetch_tuner_exception_does_not_fail_transfer():
    """A tuner that raises (bad jit compile, tuner bug) must not fail a
    transfer whose bytes are flowing fine."""
    rng = np.random.default_rng(5)
    blob = rng.integers(0, 256, size=4 * MB, dtype=np.uint8).tobytes()
    servers = _mirrors(blob, [80 * MB])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]

        class ExplodingTuner:
            def update(self, t):
                raise RuntimeError("tuner boom")

        client = MDTPClient(replicas, tuner=ExplodingTuner())
        buf, report = asyncio.run(client.fetch(
            len(blob), tune_interval_bytes=MB))
        assert bytes(buf) == blob
        assert report.retunes == 0
    finally:
        for s in servers:
            s.stop()


def test_fetch_offset_requests_shifted_window():
    rng = np.random.default_rng(2)
    blob = rng.integers(0, 256, size=4 * MB, dtype=np.uint8).tobytes()
    servers = _mirrors(blob, [80 * MB])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        client = MDTPClient(replicas, params=ChunkParams(256 * 1024, MB))
        buf, _ = asyncio.run(client.fetch(2 * MB, offset=1 * MB))
        assert bytes(buf) == blob[1 * MB:3 * MB]
    finally:
        for s in servers:
            s.stop()


def test_fetch_without_tuner_unchanged():
    rng = np.random.default_rng(3)
    blob = rng.integers(0, 256, size=2 * MB, dtype=np.uint8).tobytes()
    servers = _mirrors(blob, [80 * MB])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        client = MDTPClient(replicas, params=ChunkParams(256 * 1024, MB))
        buf, report = asyncio.run(client.fetch(len(blob)))
        assert bytes(buf) == blob
        assert report.retunes == 0
    finally:
        for s in servers:
            s.stop()
