"""Multi-source data pipeline: determinism + correctness over HTTP mirrors."""

import numpy as np
import pytest

from repro.data import (MultiSourcePipeline, TokenDatasetSpec,
                        synthetic_tokens, write_token_dataset)
from repro.transfer import RangeServer, Throttle

MB = 1024 * 1024


@pytest.fixture(scope="module")
def dataset():
    tokens = synthetic_tokens(200_000, vocab=50_000, seed=3)
    blobs = write_token_dataset(None, tokens)
    return tokens, blobs


def _mirrors(blobs, bws):
    servers = []
    for bw in bws:
        s = RangeServer(throttle=Throttle(bytes_per_s=bw)).start()
        for name, data in blobs.items():
            s.add_blob("/ds/" + name, data)
        servers.append(s)
    return servers


def test_ranges_deterministic(dataset):
    tokens, _ = dataset
    spec = TokenDatasetSpec(n_tokens=tokens.size, seq_len=128, global_batch=8)
    a = spec.ranges_for_step(5)
    b = spec.ranges_for_step(5)
    assert a == b
    assert len(a) == 8
    assert all(l == (128 + 1) * 4 for _, l in a)
    # different steps -> different ranges
    assert spec.ranges_for_step(6) != a


def test_host_slicing_partitions_batch(dataset):
    tokens, _ = dataset
    spec = TokenDatasetSpec(n_tokens=tokens.size, seq_len=64, global_batch=8)
    all_rows = spec.ranges_for_step(2)
    got = []
    for host in range(4):
        got.extend(spec.ranges_for_step(2, host=host, n_hosts=4))
    assert got == all_rows


def test_pipeline_matches_direct_slicing(dataset):
    tokens, blobs = dataset
    spec = TokenDatasetSpec(n_tokens=tokens.size, seq_len=128, global_batch=4)
    servers = _mirrors(blobs, [20 * MB, 40 * MB, 80 * MB])
    try:
        from repro.transfer import Replica
        replicas = [Replica("127.0.0.1", s.port, "/ds") for s in servers]
        pipe = MultiSourcePipeline(replicas, spec, depth=2)
        try:
            for step in range(3):
                batch = pipe.get_batch(step)
                assert batch.shape == (4, 129)
                for i in range(4):
                    start = ((step * 4 + i) * 128) % (tokens.size - 129)
                    np.testing.assert_array_equal(
                        batch[i], tokens[start:start + 129])
        finally:
            pipe.close()
    finally:
        for s in servers:
            s.stop()


def test_pipeline_prefetch_out_of_order_consume(dataset):
    tokens, blobs = dataset
    spec = TokenDatasetSpec(n_tokens=tokens.size, seq_len=64, global_batch=2)
    servers = _mirrors(blobs, [50 * MB])
    try:
        from repro.transfer import Replica
        replicas = [Replica("127.0.0.1", s.port, "/ds") for s in servers]
        pipe = MultiSourcePipeline(replicas, spec, depth=3)
        try:
            b2 = pipe.get_batch(2)
            b0 = pipe.get_batch(0)
            assert b2.shape == b0.shape == (2, 65)
            start0 = 0
            np.testing.assert_array_equal(b0[0], tokens[0:65])
        finally:
            pipe.close()
    finally:
        for s in servers:
            s.stop()
