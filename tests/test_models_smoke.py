"""Per-architecture smoke tests: reduced configs, one forward + one decode
step on CPU, asserting output shapes and finiteness (assignment item f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, reduced_config, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.models.common import init_params, spec_tree_num_params
from repro.models import transformer as T

B, S = 2, 32


def _batch(cfg, key):
    kt, kf = jax.random.split(key)
    batch = {"tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kf, (B, S, cfg.frontend_dim),
                                            jnp.float32).astype(jnp.bfloat16)
        batch["tokens"] = jax.random.randint(kt, (B, 16), 0, cfg.vocab_size)
    elif cfg.family == "vlm":
        batch["patches"] = jax.random.normal(kf, (B, 8, cfg.frontend_dim),
                                             jnp.float32).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_and_loss(arch):
    cfg = reduced_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, T.model_specs(cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = T.forward(params, cfg, batch)
    S_dec = batch["tokens"].shape[1]
    assert logits.shape == (B, S_dec, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss = T.lm_loss(params, cfg, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss))
    assert float(loss) > 0.0
    # a rough sanity anchor: untrained loss ~ ln(V)
    assert float(loss) < 3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", list_archs())
def test_train_grad_step(arch):
    """One SGD step decreases loss on a fixed batch (learnability smoke)."""
    cfg = reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), T.model_specs(cfg))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss_fn = lambda p: T.lm_loss(p, cfg, batch)
    l0, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    lr = 0.3 / max(float(gnorm), 1.0)
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), (float(l0), float(l1))


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step(arch):
    cfg = reduced_config(arch)
    params = init_params(jax.random.PRNGKey(0), T.model_specs(cfg))
    mem_len = 8 if cfg.family in ("encdec", "vlm") else 0
    cache = T.init_cache(cfg, B, 16, mem_len)
    if mem_len:
        cache["memory"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, mem_len, cfg.d_model),
            jnp.float32).astype(cfg.jdtype)
    token = jnp.zeros((B, 1), jnp.int32)
    logits, cache = T.decode_step(params, cfg, cache, token, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # second step advances without shape drift
    logits2, cache2 = T.decode_step(params, cfg, cache, token, jnp.int32(1))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce full-forward logits (qwen3)."""
    cfg = reduced_config("qwen3-1.7b")
    params = init_params(jax.random.PRNGKey(0), T.model_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, {"tokens": toks})
    cache = T.init_cache(cfg, 1, 8)
    outs = []
    for t in range(8):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        atol=0.08, rtol=0.05)


def test_decode_matches_forward_ssm():
    """Same check through the recurrent paths (xlstm: mLSTM+sLSTM)."""
    cfg = reduced_config("xlstm-125m")
    params = init_params(jax.random.PRNGKey(0), T.model_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, {"tokens": toks})
    cache = T.init_cache(cfg, 1, 8)
    outs = []
    for t in range(8):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        atol=0.08, rtol=0.05)


def test_decode_matches_forward_hybrid():
    """And through mamba2 + shared attention (zamba2)."""
    cfg = reduced_config("zamba2-7b")
    params = init_params(jax.random.PRNGKey(0), T.model_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, {"tokens": toks})
    cache = T.init_cache(cfg, 1, 8)
    outs = []
    for t in range(8):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                  jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32), np.asarray(full_logits, np.float32),
        atol=0.08, rtol=0.05)


def test_full_config_param_counts():
    """Full (paper-table) configs hit their published param scales."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "olmoe-1b-7b": (5.5e9, 8.5e9),
        "qwen2.5-14b": (12e9, 16e9),
        "qwen3-1.7b": (1.4e9, 2.4e9),
        "nemotron-4-15b": (13e9, 17.5e9),
        "gemma3-1b": (0.7e9, 1.4e9),
        "whisper-large-v3": (1.2e9, 1.9e9),
        "zamba2-7b": (6e9, 9e9),
        "llama-3.2-vision-11b": (8.5e9, 12e9),
        "xlstm-125m": (0.1e9, 0.2e9),
    }
    from repro.models.transformer import model_specs
    for arch, (lo, hi) in expect.items():
        n = spec_tree_num_params(model_specs(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]B"


def test_moe_active_params():
    n_act = T.active_params(get_config("kimi-k2-1t-a32b"))
    assert 25e9 <= n_act <= 40e9, f"kimi active {n_act/1e9:.1f}B"


def test_long_500k_applicability():
    ok = {a: applicable(get_config(a), SHAPES["long_500k"])[0]
          for a in list_archs()}
    assert ok["zamba2-7b"] and ok["xlstm-125m"] and ok["gemma3-1b"]
    assert not ok["qwen2.5-14b"] and not ok["kimi-k2-1t-a32b"]
    assert sum(ok.values()) == 3
