"""Make ``src/`` importable regardless of how pytest is invoked.

NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
benches must see the real single-device CPU backend.  Only
``src/repro/launch/dryrun.py`` (run as its own process) forces 512 host
devices.
"""

import os
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(_SRC) not in (os.path.abspath(p) for p in sys.path):
    sys.path.insert(0, os.path.abspath(_SRC))
