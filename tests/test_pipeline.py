"""Pipeline-parallelism tests: GPipe schedule over a 'pod' axis must be
numerically equivalent to the plain forward (same params, same batch).

Multi-device semantics need >1 device, so the real check runs in a
subprocess with 4 forced host devices (mesh (2,2) = pod x data)."""

import os
import subprocess
import sys
import textwrap

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.distributed.context import ShardingRules, activate
    from repro.distributed.pipeline import make_pp_forward, pp_lm_loss
    from repro.models.common import init_params
    from repro.models.transformer import lm_loss, model_specs

    cfg = get_config("qwen3-1.7b").replace(
        name="pp-test", n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=256, remat="none", microbatches=1,
        dtype="float32")
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    rules = ShardingRules().override(layers="pod", qheads=None,
                                     kv_heads=None, mlp=None)

    key = jax.random.key(0)
    params = init_params(key, model_specs(cfg), jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, 256)
    batch = {"tokens": tokens}

    with activate(mesh, rules):
        ref = jax.jit(lambda p, b: lm_loss(p, cfg, b))(params, batch)
        fwd = make_pp_forward(cfg, mesh, n_microbatches=2)
        pp = jax.jit(lambda p, b: pp_lm_loss(p, cfg, b, fwd))(params, batch)
        assert np.allclose(float(ref), float(pp), rtol=2e-4, atol=2e-4), \\
            (float(ref), float(pp))

        g_ref = jax.jit(jax.grad(lambda p, b: lm_loss(p, cfg, b)))(
            params, batch)
        g_pp = jax.jit(jax.grad(lambda p, b: pp_lm_loss(p, cfg, b, fwd)))(
            params, batch)
        flat_r = jax.tree.leaves(g_ref)
        flat_p = jax.tree.leaves(g_pp)
        for a, b_ in zip(flat_r, flat_p):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=2e-3, atol=2e-3)

        # bubble accounting: the compiled HLO must contain the
        # collective-permute ring (the PP hand-off)
        txt = jax.jit(lambda p, b: pp_lm_loss(p, cfg, b, fwd)).lower(
            params, batch).compile().as_text()
        assert "collective-permute" in txt
    print("PP_OK")
""")


def test_pp_matches_reference():
    env = dict(os.environ, PYTHONPATH=_SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _PROG],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, (res.stderr[-3000:], res.stdout[-500:])
    assert "PP_OK" in res.stdout
