"""Block-compressed range dataplane: codec framing/decode units, the
compressed-range server path end to end, and the wire-vs-decoded
telemetry split the codec forces on the client.
"""

import asyncio
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.transfer import MDTPClient, RangeServer, Replica, Throttle
from repro.transfer import codec
from repro.transfer.sink import BufferSink

KB = 1024
MB = 1024 * 1024


def _compressible(n: int, seed: int = 7) -> bytes:
    """~n bytes that zlib crushes hard but that aren't degenerate: long
    runs punctuated by a pseudo-random byte each KB."""
    rng = np.random.default_rng(seed)
    arr = np.zeros(n, dtype=np.uint8)
    arr[::KB] = rng.integers(0, 256, size=len(arr[::KB]), dtype=np.uint8)
    return arr.tobytes()


def _random(n: int, seed: int = 9) -> bytes:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()


# --------------------------------------------------------------------------
# codec units
# --------------------------------------------------------------------------

def test_roundtrip_exact_ranges():
    data = _random(3 * 64 * KB + 17)            # non-block-aligned tail
    store = codec.compress_blocks(data, 64 * KB)
    assert store.total == len(data)
    for lo, hi in [(0, len(data) - 1),           # whole blob
                   (0, 64 * KB - 1),             # exactly one block
                   (64 * KB, 2 * 64 * KB - 1),   # interior block
                   (10, 20),                     # inside one block
                   (64 * KB - 3, 64 * KB + 3),   # straddles a boundary
                   (3 * 64 * KB, len(data) - 1),  # the short tail block
                   (len(data) - 1, len(data) - 1)]:  # final byte
        payload = store.encode_range(lo, hi)
        assert codec.decode_range(payload, lo, hi) == data[lo:hi + 1]


def test_wire_length_counts_only_covering_blocks():
    data = _compressible(8 * 64 * KB)
    store = codec.compress_blocks(data, 64 * KB)
    assert store.wire_total < store.total        # it actually compresses
    one = store.wire_length(0, 10)               # one block's frames
    assert one == len(store.encode_range(0, 10))
    assert one < store.wire_total


def test_decode_into_buffer():
    data = _random(200 * KB)
    store = codec.compress_blocks(data, 64 * KB)
    lo, hi = 100, 150 * KB
    out = bytearray(hi - lo + 1)
    n = codec.decode_range_into(store.encode_range(lo, hi), lo, hi, out)
    assert n == hi - lo + 1 and bytes(out) == data[lo:hi + 1]


def test_torn_frames_raise_codec_error():
    data = _random(130 * KB)
    store = codec.compress_blocks(data, 64 * KB)
    payload = store.encode_range(0, len(data) - 1)
    with pytest.raises(codec.CodecError):
        codec.decode_range(payload[:8], 0, len(data) - 1)   # torn header
    with pytest.raises(codec.CodecError):
        codec.decode_range(payload[:-5], 0, len(data) - 1)  # torn payload
    # frames that skip the requested span (a gap)
    tail = store.encode_range(64 * KB, len(data) - 1)
    with pytest.raises(codec.CodecError):
        codec.decode_range(tail, 0, len(data) - 1)
    # corrupt compressed bytes
    bad = bytearray(payload)
    bad[20] ^= 0xFF
    with pytest.raises(codec.CodecError):
        codec.decode_range(bytes(bad), 0, len(data) - 1)
    # CodecError is a ConnectionError, so the client's per-request
    # failure path (ban + refetch elsewhere) handles a torn body
    assert issubclass(codec.CodecError, ConnectionError)


def test_encoding_header_roundtrip():
    h = codec.encoding_header(256 * KB)
    assert codec.parse_encoding(h) == 256 * KB
    assert codec.parse_encoding(None) is None
    assert codec.parse_encoding("identity") is None
    assert codec.parse_encoding("zblock") is None        # missing block=
    assert codec.parse_encoding("zblock; block=nope") is None


def test_decode_range_async_inline_and_offloaded():
    small = _random(16 * KB)                     # <= inline threshold
    big = _compressible(4 * MB)                  # > threshold: executor

    async def run(data, block):
        store = codec.compress_blocks(data, block)
        lo, hi = 3, len(data) - 2
        out = bytearray(hi - lo + 1)
        await codec.decode_range_async(store.encode_range(lo, hi),
                                       lo, hi, out=out)
        assert bytes(out) == data[lo:hi + 1]
        got = await codec.decode_range_async(store.encode_range(lo, hi),
                                             lo, hi)
        assert bytes(got) == data[lo:hi + 1]

    asyncio.run(run(small, 8 * KB))
    asyncio.run(run(big, 256 * KB))


# --------------------------------------------------------------------------
# compressed-range server path, end to end
# --------------------------------------------------------------------------

def test_compressed_fetch_end_to_end():
    blob = _compressible(8 * MB)
    s = RangeServer().start()
    s.add_compressed_blob("/data", blob, block_size=256 * KB)
    try:
        client = MDTPClient([Replica("127.0.0.1", s.port, "/data")])
        data, report = asyncio.run(client.fetch(len(blob)))
        assert hashlib.sha256(data).hexdigest() == \
            hashlib.sha256(blob).hexdigest()
        # commit-side accounting is DECODED bytes
        assert report.total_bytes == len(blob)
        assert sum(report.bytes_per_replica.values()) == len(blob)
    finally:
        s.stop()


def test_compressed_offset_fetch_into_sink():
    blob = _compressible(4 * MB, seed=11)
    s = RangeServer().start()
    s.add_compressed_blob("/data", blob, block_size=128 * KB)
    try:
        client = MDTPClient([Replica("127.0.0.1", s.port, "/data")])
        off, n = 700 * KB + 13, 2 * MB
        sink = BufferSink(len(blob))
        _, report = asyncio.run(
            client.fetch(n, sink=sink, offset=off))
        assert bytes(sink.view[off:off + n]) == blob[off:off + n]
        assert report.total_bytes == n
    finally:
        s.stop()


def test_compressed_and_raw_mirrors_mix():
    blob = _compressible(8 * MB, seed=13)
    comp = RangeServer().start()
    comp.add_compressed_blob("/data", blob)
    raw = RangeServer().start()
    raw.add_blob("/data", blob)
    try:
        reps = [Replica("127.0.0.1", comp.port, "/data"),
                Replica("127.0.0.1", raw.port, "/data")]
        client = MDTPClient(reps)
        data, report = asyncio.run(client.fetch(len(blob)))
        assert hashlib.sha256(data).hexdigest() == \
            hashlib.sha256(blob).hexdigest()
        assert all(report.bytes_per_replica[r.name] > 0 for r in reps)
        assert sum(report.bytes_per_replica.values()) == len(blob)
    finally:
        comp.stop()
        raw.stop()


def test_compressed_checksum_covers_decoded_bytes():
    # the server's X-Range-Checksum is over the pristine DECODED range,
    # so the client's CRC verification needs no codec awareness; a fetch
    # with verification on must pass with zero refetches
    blob = _compressible(2 * MB, seed=17)
    s = RangeServer(checksums=True).start()
    s.add_compressed_blob("/data", blob)
    try:
        client = MDTPClient([Replica("127.0.0.1", s.port, "/data")],
                            verify_integrity=True)
        data, report = asyncio.run(client.fetch(len(blob)))
        assert data == blob
        assert report.refetched_ranges == 0
    finally:
        s.stop()


def test_telemetry_is_wire_bytes_commit_is_decoded():
    """The double-count regression the codec makes possible: bandwidth
    estimates must meter WIRE bytes (what the throttled pipe carried),
    while the report/sink totals stay in DECODED bytes.  Crediting
    decoded bytes to the estimator would claim ~10x the throttle."""
    blob = _compressible(6 * MB, seed=19)
    rate = 8 * MB
    s = RangeServer(
        throttle=Throttle(bytes_per_s=rate, deterministic=True)).start()
    s.add_compressed_blob("/data", blob, block_size=256 * KB)
    try:
        rep = Replica("127.0.0.1", s.port, "/data")
        client = MDTPClient([rep])
        data, report = asyncio.run(client.fetch(len(blob)))
        assert data == blob
        # decoded side: the full blob committed
        assert report.total_bytes == len(blob)
        assert report.bytes_per_replica[rep.name] == len(blob)
        # wire side: the deterministic token bucket paces wire bytes at
        # `rate`; the payload compresses ~10x, so a decoded-bytes
        # estimate would read ~10x the throttle.  Allow generous slack
        # for connect/header overheads, but stay far below the decoded
        # goodput (which this fetch demonstrably exceeds).
        est = report.observed_throughputs[rep.name]
        wire = s.served_bytes
        assert wire < len(blob) / 4              # it really compressed
        assert est < 3 * rate                    # wire-metered, not decoded
        decoded_goodput = len(blob) / report.elapsed
        assert decoded_goodput > 3 * rate        # the codec's actual win
    finally:
        s.stop()


def test_compressed_checkpoint_restore(tmp_path):
    """Restore streams through the compressed dataplane transparently:
    mirrors serve data.bin block-compressed, leaves land bit-exact."""
    state = {"params": {"w": jax.random.normal(jax.random.PRNGKey(2),
                                               (256, 256)),
                        "b": jnp.zeros((4096,), jnp.float32)},
             "step": jnp.int32(9)}
    d = save_checkpoint(str(tmp_path), 42, state)
    servers = []
    for _ in range(2):
        s = RangeServer().start()
        base = "/ckpt/step_0000000042"
        s.add_file(base + "/manifest.json",
                   os.path.join(d, "manifest.json"))
        s.add_compressed_file(base + "/data.bin",
                              os.path.join(d, "data.bin"),
                              block_size=128 * KB)
        servers.append(s)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/ckpt") for s in servers]
        restored, step = restore_checkpoint(
            str(tmp_path), state, step=42, replicas=replicas)
        assert step == 42
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        for s in servers:
            s.stop()
