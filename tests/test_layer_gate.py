"""The layering gate (``tools/layercheck.py``) stays clean and sharp.

CI's lint job runs the same script; having it in tier-1 means a stray
``import asyncio`` (or a transitive hop into JAX) inside the sans-I/O
scheduling core fails the suite everywhere, not just where the lint job
runs.  The unit tests drive the AST walker on synthetic trees so both
directions are covered: it must flag real violations (including
transitive and conditional ones) and must not flag clean layers.
"""

import os
import subprocess
import sys
import textwrap

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _layercheck():
    sys.path.insert(0, os.path.join(_ROOT, "tools"))
    try:
        import layercheck
    finally:
        sys.path.pop(0)
    return layercheck


def _write_tree(root, files):
    for rel, body in files.items():
        path = os.path.join(root, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            f.write(textwrap.dedent(body))


def test_repo_layering_is_clean():
    res = subprocess.run(
        [sys.executable, os.path.join("tools", "layercheck.py")],
        capture_output=True, text=True, cwd=_ROOT, timeout=120)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "layer check clean" in res.stdout


def test_direct_violation_flagged(tmp_path):
    lc = _layercheck()
    _write_tree(tmp_path, {
        "pkg/__init__.py": "",
        "pkg/core.py": "import asyncio\n",
    })
    v = lc.check_contract("pkg", ("asyncio",), src=str(tmp_path))
    assert len(v) == 1 and "must not reach asyncio" in v[0], v


def test_transitive_violation_flagged(tmp_path):
    # pkg -> helper (outside pkg, same tree) -> socket: the walker must
    # follow the edge out of the root package and still flag it
    lc = _layercheck()
    _write_tree(tmp_path, {
        "pkg/__init__.py": "from helper import thing\n",
        "helper.py": "import socket\n\nthing = 1\n",
    })
    v = lc.check_contract("pkg", ("socket",), src=str(tmp_path))
    assert v and "socket" in v[0], v


def test_conditional_and_from_imports_flagged(tmp_path):
    # an import inside a function body (lazy) and a ``from jax import
    # numpy`` both count — laziness is still coupling
    lc = _layercheck()
    _write_tree(tmp_path, {
        "pkg/__init__.py": "",
        "pkg/lazy.py": "def f():\n    import jax\n    return jax\n",
        "pkg/fromimp.py": "from jax import numpy as jnp\n",
    })
    v = lc.check_contract("pkg", ("jax",), src=str(tmp_path))
    assert len(v) == 2, v


def test_relative_imports_resolve(tmp_path):
    # ``from .sibling import x`` where sibling imports a forbidden
    # module: relative edges must resolve against the package
    lc = _layercheck()
    _write_tree(tmp_path, {
        "pkg/__init__.py": "from .sub import x\n",
        "pkg/sub.py": "from ._impl import x\n",
        "pkg/_impl.py": "import ssl\nx = 1\n",
    })
    v = lc.check_contract("pkg", ("ssl",), src=str(tmp_path))
    assert v and "_impl.py" in v[0], v


def test_clean_layer_passes(tmp_path):
    lc = _layercheck()
    _write_tree(tmp_path, {
        "pkg/__init__.py": "from . import core\n",
        "pkg/core.py": "import math\nimport heapq\n"
                       "from dataclasses import dataclass\n",
    })
    assert lc.check_contract("pkg", ("asyncio", "socket", "jax"),
                             src=str(tmp_path)) == []


def test_missing_package_reported(tmp_path):
    lc = _layercheck()
    v = lc.check_contract("nope", ("asyncio",), src=str(tmp_path))
    assert v and "not found" in v[0]


def test_sched_contract_is_registered():
    # the gate only protects what its CONTRACTS table names — make sure
    # the sched purity promise can't be dropped silently
    lc = _layercheck()
    assert "repro.transfer.sched" in lc.CONTRACTS
    banned = lc.CONTRACTS["repro.transfer.sched"]
    for must in ("asyncio", "socket", "jax"):
        assert must in banned
