"""Hypothesis property tests over the whole transfer system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    Aria2Policy,
    BitTorrentPolicy,
    ChunkParams,
    MDTPPolicy,
    StaticChunkingPolicy,
    simulate,
)
from repro.core.simulator import ServerSpec

MB = 1024 * 1024

_server_sets = st.lists(
    st.tuples(
        st.floats(min_value=0.5, max_value=200.0),   # MiB/s
        st.floats(min_value=0.0, max_value=0.2),     # rtt
    ),
    min_size=1,
    max_size=8,
)
_policies = st.sampled_from(["mdtp", "mdtp_fgl", "static", "aria2", "bt"])


def _policy(name):
    return {
        "mdtp": lambda: MDTPPolicy(),
        "mdtp_fgl": lambda: MDTPPolicy(
            params=ChunkParams(2 * MB, 20 * MB, mode="fast_get_large")
        ),
        "static": lambda: StaticChunkingPolicy(),
        "aria2": lambda: Aria2Policy(),
        "bt": lambda: BitTorrentPolicy(),
    }[name]()


@settings(max_examples=60, deadline=None)
@given(
    servers=_server_sets,
    size_mb=st.integers(min_value=1, max_value=512),
    policy=_policies,
    seed=st.integers(0, 2**31 - 1),
)
def test_transfer_invariants(servers, size_mb, policy, seed):
    """For ANY servers/size/policy/seed:
    1. the transfer completes,
    2. delivered ranges exactly tile [0, size) (each byte exactly once),
    3. completion time respects the aggregate-capacity lower bound,
    4. per-server bytes are non-negative and sum to the file size."""
    specs = [
        ServerSpec(name=f"s{i}", bandwidth=bw * MB, rtt=rtt)
        for i, (bw, rtt) in enumerate(servers)
    ]
    size = size_mb * MB
    r = simulate(_policy(policy), specs, size, seed=seed)
    r.check_integrity()
    assert sum(r.bytes_per_server) == size
    agg = sum(s.bandwidth for s in specs)
    assert r.total_time >= size / agg * 0.999
    assert all(b >= 0 for b in r.bytes_per_server)


@settings(max_examples=40, deadline=None)
@given(
    servers=_server_sets,
    size_mb=st.integers(min_value=8, max_value=256),
    fail_t=st.floats(min_value=0.1, max_value=5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_failure_reallocation_invariant(servers, size_mb, fail_t, seed):
    """Kill one replica mid-transfer: MDTP must still deliver every byte
    exactly once via reallocation (framework checkpoint-restore relies on
    this)."""
    specs = [
        ServerSpec(name=f"s{i}", bandwidth=bw * MB, rtt=rtt)
        for i, (bw, rtt) in enumerate(servers)
    ]
    # survivor guaranteed:
    specs.append(ServerSpec(name="survivor", bandwidth=20 * MB, rtt=0.01))
    specs[0] = ServerSpec(
        name="victim", bandwidth=specs[0].bandwidth, rtt=specs[0].rtt,
        fail_at=fail_t,
    )
    size = size_mb * MB
    r = simulate(MDTPPolicy(), specs, size, seed=seed)
    r.check_integrity()
    assert sum(r.bytes_per_server) == size
    late = [c for c in r.chunks if c.server == 0 and c.t_request > fail_t]
    assert late == []


@settings(max_examples=60, deadline=None)
@given(
    ths=st.lists(
        st.one_of(st.just(0.0), st.floats(min_value=0.01, max_value=1e7)),
        min_size=1, max_size=10,
    ),
    remaining=st.integers(0, 2**40),
)
def test_jax_allocator_matches_python(ths, remaining):
    """jax_alloc.chunk_sizes must mirror chunking.round_chunk_sizes to
    float32 precision (<= 64 bytes at 160 MB chunk scale)."""
    jnp_mod = pytest.importorskip("jax.numpy")
    from repro.core.chunking import round_chunk_sizes
    from repro.core.jax_alloc import chunk_sizes

    params = ChunkParams(4 * MB, 40 * MB)
    py = np.array(round_chunk_sizes(ths, params, remaining), dtype=np.float64)
    jx = np.array(chunk_sizes(jnp_mod.asarray(ths, jnp_mod.float32),
                              float(remaining), params))
    # float32 ulp at 2**40 is 2**17; tolerance covers the remaining-clamp case
    tol = np.maximum(64.0, np.abs(py) * 2e-7)
    np.testing.assert_allclose(jx, py, atol=float(tol.max()))


@settings(max_examples=15, deadline=None)
@given(
    rates=st.lists(st.floats(min_value=2.0, max_value=100.0), min_size=2, max_size=6),
    size_mb=st.integers(min_value=32, max_value=512),
)
def test_jax_sim_matches_python_sim(rates, size_mb):
    """The on-device simulator and the Python simulator agree (no jitter)."""
    from repro.core.jax_sim import simulate_transfer

    rtt = 0.02
    size = size_mb * MB
    params = ChunkParams(2 * MB, 20 * MB)
    specs = [ServerSpec(name=f"s{i}", bandwidth=r * MB, rtt=rtt, jitter=0.0)
             for i, r in enumerate(rates)]
    py = simulate(MDTPPolicy(params=params), specs, size, seed=0)
    jx = simulate_transfer([r * MB for r in rates], rtt, size, params)
    assert float(jx.total_time) == pytest.approx(py.total_time, rel=0.02)
    np.testing.assert_allclose(
        np.array(jx.bytes_per_server), np.array(py.bytes_per_server),
        rtol=0.05, atol=2 * params.large_chunk,
    )
