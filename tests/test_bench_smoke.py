"""Smoke-bench guard: the autotune section of ``benchmarks.run`` must
complete (and demonstrate its speedup) in under a minute on one CPU core,
so the tuner-fusion claim stays continuously verified."""

import os
import subprocess
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_autotune_bench_smoke():
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run",
         "--skip", "fig2", "fig3", "fig4", "fig5", "table2", "roofline",
         "restore"],
        capture_output=True, text=True, cwd=_ROOT, timeout=120,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    out = res.stdout
    assert "# === autotune ===" in out
    # The bench's own output carries the headline number (>= 5x on an idle
    # box); the guard only enforces a loose floor so a loaded CI core
    # can't flake the suite while a true regression to per-point-compile
    # behavior (ratio ~1x) still fails.
    cold = [l for l in out.splitlines() if l.startswith("autotune/fused_cold")]
    assert cold, out
    speedup = float(cold[0].rsplit("speedup=", 1)[1].rstrip("x"))
    assert speedup >= 2.0, cold[0]
    agree = [l for l in out.splitlines()
             if l.startswith("autotune/argmin_agree")]
    assert agree and agree[0].endswith("True"), agree
