"""Smoke-bench guard: the autotune section of ``benchmarks.run`` must
complete (and demonstrate its speedups) quickly on one CPU core, so the
tuner-fusion and round-engine claims stay continuously verified, and the
``--json`` artifact (BENCH_autotune.json — the cross-PR perf trajectory)
must be valid machine-readable JSON."""

import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="module")
def bench_run(tmp_path_factory):
    json_path = tmp_path_factory.mktemp("bench") / "BENCH_autotune.json"
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run",
         "--skip", "fig2", "fig3", "fig4", "fig5", "table2", "roofline",
         "restore", "--json", str(json_path)],
        capture_output=True, text=True, cwd=_ROOT, timeout=420,
    )
    return res, json_path


def test_autotune_bench_smoke(bench_run):
    res, _ = bench_run
    assert res.returncode == 0, res.stderr[-2000:]
    out = res.stdout
    assert "# === autotune ===" in out
    # The bench's own output carries the headline number (>= 5x on an idle
    # box); the guard only enforces a loose floor so a loaded CI core
    # can't flake the suite while a true regression to per-point-compile
    # behavior (ratio ~1x) still fails.
    cold = [l for l in out.splitlines() if l.startswith("autotune/fused_cold")]
    assert cold, out
    speedup = float(cold[0].rsplit("speedup=", 1)[1].rstrip("x"))
    assert speedup >= 2.0, cold[0]
    agree = [l for l in out.splitlines()
             if l.startswith("autotune/argmin_agree")]
    assert agree and agree[0].endswith("True"), agree


def test_round_engine_bench_speedup(bench_run):
    """The round engine's steady-state win over the event engine shows in
    the bench (headline >= 5x idle; loose 2x floor against CI noise), and
    its argmin regret under the event metric stays small."""
    res, _ = bench_run
    out = res.stdout
    row = [l for l in out.splitlines()
           if l.startswith("autotune/engine_round")]
    assert row, out
    speedup = float(row[0].rsplit("speedup=", 1)[1].split(",")[0].rstrip("x"))
    assert speedup >= 2.0, row[0]
    regret = [l for l in out.splitlines()
              if l.startswith("autotune/engine_regret")]
    assert regret, out
    assert float(regret[0].split(",")[2]) <= 0.02, regret[0]


def test_bench_json_artifact_valid(bench_run):
    """--json writes well-formed JSON carrying µs/call for every emitted
    row, including the event-vs-round engine comparison."""
    res, json_path = bench_run
    assert res.returncode == 0, res.stderr[-2000:]
    assert json_path.exists()
    payload = json.loads(json_path.read_text())
    assert payload["schema"] == 1
    assert payload["failed_sections"] == []
    names = [r["name"] for r in payload["rows"]]
    assert any(n.startswith("autotune/engine_event") for n in names)
    assert any(n.startswith("autotune/engine_round") for n in names)
    for row in payload["rows"]:
        assert isinstance(row["us_per_call"], float)


def test_contention_bench_rows(bench_run):
    """The contention section emits manager-vs-greedy rows for every
    trace (makespan derived, vs_greedy extra on the manager rows)."""
    res, json_path = bench_run
    out = res.stdout
    assert "# === contention ===" in out
    rows = [l for l in out.splitlines() if l.startswith("contention/")]
    mgr_rows = [l for l in rows if "/manager," in l]
    greedy_rows = [l for l in rows if "/greedy," in l]
    assert len(mgr_rows) == 3 and len(greedy_rows) == 3, rows
    assert all("vs_greedy=" in l for l in mgr_rows)


def test_committed_bench_online_contention_wins():
    """The committed BENCH_online.json carries the contention rows and
    records the shared-fleet manager beating K independent greedy clients
    on aggregate completion time (makespan) for >= 2 of 3 traces."""
    path = os.path.join(_ROOT, "BENCH_online.json")
    assert os.path.exists(path), "BENCH_online.json must be committed"
    payload = json.loads(open(path).read())
    rows = {r["name"]: r for r in payload["rows"]}
    traces = ("simultaneous", "staggered", "bottleneck")
    wins = 0
    for t in traces:
        greedy = rows[f"contention/{t}/greedy"]
        manager = rows[f"contention/{t}/manager"]
        if float(manager["derived"]) < float(greedy["derived"]):
            wins += 1
    assert wins >= 2, {t: (rows[f"contention/{t}/greedy"]["derived"],
                           rows[f"contention/{t}/manager"]["derived"])
                       for t in traces}


def test_dataplane_bench_rows(bench_run):
    """The dataplane section emits the loopback copy/zero-copy/pipelined
    goodput rows and the high-RTT serial-vs-pipelined pair, and on the
    high-RTT trace the pipelined client wins (the same invariant the
    ``run.py --check`` win-guard enforces in CI)."""
    res, _ = bench_run
    out = res.stdout
    assert "# === dataplane ===" in out
    rows = [l for l in out.splitlines() if l.startswith("dataplane/")]
    names = [r.split(",")[0] for r in rows]
    for n in ("dataplane/loopback/1rep/copy_serial",
              "dataplane/loopback/1rep/zerocopy_serial",
              "dataplane/loopback/1rep/zerocopy_pipelined",
              "dataplane/loopback/3rep/zerocopy_pipelined",
              "dataplane/highrtt/serial",
              "dataplane/highrtt/pipelined"):
        assert n in names, rows
    by_name = {r.split(",")[0]: r.split(",") for r in rows}
    serial = float(by_name["dataplane/highrtt/serial"][2])
    piped = float(by_name["dataplane/highrtt/pipelined"][2])
    assert piped >= serial, (serial, piped)


def test_committed_bench_dataplane_pipelined_wins():
    """The committed BENCH_dataplane.json records the pipelined zero-copy
    path beating the serial path on loopback goodput for the high-RTT
    throttled trace — the tentpole claim, pinned as an artifact."""
    path = os.path.join(_ROOT, "BENCH_dataplane.json")
    assert os.path.exists(path), "BENCH_dataplane.json must be committed"
    payload = json.loads(open(path).read())
    rows = {r["name"]: r for r in payload["rows"]}
    serial = float(rows["dataplane/highrtt/serial"]["derived"])
    piped = float(rows["dataplane/highrtt/pipelined"]["derived"])
    assert piped > serial, (serial, piped)
    # and the zero-copy receive path is not slower than the copy path
    # (loopback assembly goodput, 1-replica)
    copy = float(rows["dataplane/loopback/1rep/copy_serial"]["derived"])
    zc = float(rows["dataplane/loopback/1rep/zerocopy_serial"]["derived"])
    assert zc >= copy, (copy, zc)


def test_committed_bench_json_tracks_engines():
    """The committed BENCH_autotune.json (perf trajectory across PRs) is
    valid and records both simulator engines."""
    path = os.path.join(_ROOT, "BENCH_autotune.json")
    assert os.path.exists(path), "BENCH_autotune.json must be committed"
    payload = json.loads(open(path).read())
    names = [r["name"] for r in payload["rows"]]
    assert any(n.startswith("autotune/engine_event") for n in names)
    assert any(n.startswith("autotune/engine_round") for n in names)
