"""Shape/dtype sweeps for the decode_attention and ssm_scan Pallas kernels
(interpret mode) against their pure-jnp oracles."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    decode_attention, decode_attention_ref, ssm_scan, ssm_scan_ref,
)

RNG = np.random.default_rng(42)


def _decode_case(B=2, KV=2, G=4, hd=64, S=512, dtype=jnp.bfloat16):
    q = jnp.asarray(RNG.standard_normal((B, 1, KV * G, hd)), dtype)
    kc = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), dtype)
    vc = jnp.asarray(RNG.standard_normal((B, S, KV, hd)), dtype)
    return q, kc, vc


def _decode_ref(q, kc, vc, pos, window=None, scale=None):
    B, _, H, hd = q.shape
    S, KV = kc.shape[1], kc.shape[2]
    G = H // KV
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    qg = q[:, 0].reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kk = kc.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vv = vc.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    out = decode_attention_ref(qg, kk, vv, pos, scale=scale, window=window)
    return out.reshape(B, KV, G, hd).reshape(B, 1, H, hd)


def _close(a, b, tol):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("hd", [64, 112, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_head_dims(hd, dtype):
    q, kc, vc = _decode_case(hd=hd, dtype=dtype)
    pos = jnp.int32(300)
    out = decode_attention(q, kc, vc, pos, blk_k=128)
    _close(out, _decode_ref(q, kc, vc, pos),
           2e-2 if dtype == jnp.bfloat16 else 2e-5)


@pytest.mark.parametrize("G", [1, 2, 8])
def test_decode_gqa_ratios(G):
    q, kc, vc = _decode_case(G=G)
    pos = jnp.int32(511)  # full cache valid
    out = decode_attention(q, kc, vc, pos, blk_k=256)
    _close(out, _decode_ref(q, kc, vc, pos), 2e-2)


@pytest.mark.parametrize("pos", [0, 1, 255, 256, 500])
def test_decode_positions(pos):
    """Block skipping must be exact at block boundaries and tiny caches."""
    q, kc, vc = _decode_case()
    out = decode_attention(q, kc, vc, jnp.int32(pos), blk_k=256)
    _close(out, _decode_ref(q, kc, vc, jnp.int32(pos)), 2e-2)


@pytest.mark.parametrize("window", [32, 256, 1 << 20])
def test_decode_sliding_window(window):
    q, kc, vc = _decode_case(S=1024)
    pos = jnp.int32(900)
    out = decode_attention(q, kc, vc, pos, blk_k=256, window=window)
    _close(out, _decode_ref(q, kc, vc, pos, window=window), 2e-2)


def test_decode_ragged_cache_padding():
    """Cache length not a multiple of blk_k pads and stays exact."""
    q, kc, vc = _decode_case(S=700)
    pos = jnp.int32(600)
    out = decode_attention(q, kc, vc, pos, blk_k=256)
    _close(out, _decode_ref(q, kc, vc, pos), 2e-2)


def test_decode_matches_model_decode_attention():
    """Same numbers as the XLA decode path in repro.models.layers."""
    from repro.configs import get_config
    from repro.models.common import init_params
    from repro.models.layers import attention_from_cache, attention_specs

    cfg = get_config("qwen3-1.7b").replace(
        n_layers=1, d_model=128, n_heads=4, n_kv_heads=2, vocab_size=64)
    p = init_params(jax.random.key(0), attention_specs(cfg), jnp.bfloat16)
    B, S = 2, 256
    x = jnp.asarray(RNG.standard_normal((B, 1, 128)) * 0.1, jnp.bfloat16)
    kc = jnp.asarray(RNG.standard_normal((B, S, 2, cfg.hd)), jnp.bfloat16)
    vc = jnp.asarray(RNG.standard_normal((B, S, 2, cfg.hd)), jnp.bfloat16)
    pos = jnp.int32(100)
    y_ref, k2, v2 = attention_from_cache(p, cfg, x, kc, vc, pos)

    # recompute with the kernel on the UPDATED caches, then out-project
    from repro.models.layers import _qkv
    q, _, _ = _qkv(p, cfg, x, x, pos[None], pos[None], True)
    q = q.reshape(B, 1, cfg.n_heads, cfg.hd)
    out = decode_attention(q, k2, v2, pos, blk_k=128,
                           scale=1.0 / math.sqrt(cfg.hd))
    y = jnp.einsum("bsnh,nhd->bsd", out.reshape(B, 1, cfg.n_heads, cfg.hd),
                   p["wo"])
    _close(y, y_ref, 3e-2)


# ------------------------------------------------------------------ ssm_scan

def _ssm_case(B=2, S=256, H=8, P=32, N=16, dtype=jnp.bfloat16):
    x = jnp.asarray(RNG.standard_normal((B, S, H, P)) * 0.5, dtype)
    dt = jnp.asarray(np.abs(RNG.standard_normal((B, S, H))) * 0.1,
                     jnp.float32)
    A = jnp.asarray(-np.abs(RNG.standard_normal(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, dtype)
    Cm = jnp.asarray(RNG.standard_normal((B, S, N)) * 0.3, dtype)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssm_chunk_sweep(chunk):
    args = _ssm_case()
    y = ssm_scan(*args, chunk=chunk, head_block=4)
    _close(y, ssm_scan_ref(*args), 2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_dtypes(dtype):
    args = _ssm_case(dtype=dtype)
    y = ssm_scan(*args, chunk=64)
    _close(y, ssm_scan_ref(*args), 2e-2 if dtype == jnp.bfloat16 else 2e-4)


@pytest.mark.parametrize("HP", [(4, 16), (8, 64), (16, 32)])
def test_ssm_head_shapes(HP):
    H, P = HP
    args = _ssm_case(H=H, P=P)
    y = ssm_scan(*args, chunk=64, head_block=min(4, H))
    _close(y, ssm_scan_ref(*args), 2e-2)


def test_ssm_ragged_seq():
    args = _ssm_case(S=200)
    y = ssm_scan(*args, chunk=64)
    _close(y, ssm_scan_ref(*args), 2e-2)


def test_ssm_state_continuity():
    """Chunk boundaries must carry exact state: one long scan == the
    reference sequential recurrence everywhere, including the tail."""
    args = _ssm_case(S=512)
    y = ssm_scan(*args, chunk=128)
    ref = ssm_scan_ref(*args)
    _close(y[:, -32:], ref[:, -32:], 2e-2)


def test_ssm_matches_model_ssd():
    """Kernel vs the model's chunked SSD implementation."""
    from repro.models.ssm import _ssd_chunked
    x, dt, A, Bm, Cm = _ssm_case(S=256)
    y_model, _ = _ssd_chunked(x, dt, A, Bm, Cm, chunk=64)
    y_kernel = ssm_scan(x, dt, A, Bm, Cm, chunk=64)
    _close(y_kernel, y_model.astype(y_kernel.dtype), 2e-2)
