"""Gradient-compression tests: quantization exactness bounds, error
feedback convergence, and multi-device wire semantics.

The multi-device cases run in a subprocess with 8 forced host devices
(jax locks the device count at first init, and the main test process
must keep seeing ONE device for every other test)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import dequantize_int8, quantize_int8

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    # symmetric int8: |err| <= scale/2 = max|x|/254
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 254 + 1e-7


def test_quantize_preserves_zero_and_extremes():
    x = jnp.asarray([0.0, 1.0, -1.0, 0.5], jnp.float32)
    q, s = quantize_int8(x)
    d = np.asarray(dequantize_int8(q, s))
    assert d[0] == 0.0
    np.testing.assert_allclose(d[1:3], [1.0, -1.0], rtol=1e-2)


def test_error_feedback_tracks_exact_mean():
    """EF compressed SGD sum tracks the exact sum over steps (single
    'device' = quantization error only)."""
    rng = np.random.default_rng(1)
    exact_acc = np.zeros(512, np.float32)
    comp_acc = np.zeros(512, np.float32)
    err = jnp.zeros(512, jnp.float32)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(512), jnp.float32)
        exact_acc += np.asarray(g)
        q, s = quantize_int8(g + err)
        sent = dequantize_int8(q, s)
        err = (g + err) - sent
        comp_acc += np.asarray(sent)
    # error feedback: the residual is bounded (one quantization step),
    # not accumulating over the 50 steps
    resid = np.abs(exact_acc - comp_acc)
    one_step_bound = np.abs(exact_acc).max() / 254 * 5  # loose
    assert resid.max() < max(one_step_bound, 0.2), resid.max()


_MULTIDEV_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.optim.compression import (
        compressed_mean, compressed_reduce_scatter)

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g_local = jnp.asarray(rng.standard_normal((8, 1024)), jnp.float32)

    # ---- compressed_reduce_scatter: int8 wire, f32 shard out
    def rs(g):
        return compressed_reduce_scatter(g[0], "data")
    out = shard_map(rs, mesh=mesh, in_specs=P("data"),
                        out_specs=P("data"))(g_local)
    got = np.asarray(out).reshape(-1)          # concat of 8 shards
    want = np.asarray(jnp.mean(g_local, axis=0)).reshape(-1)
    err = np.abs(got - want)
    tol = np.abs(want).max() / 100  # int8 quant bound, 8-way mean
    assert err.max() < max(tol, 0.05), ("rs", err.max())

    # ---- wire dtype check: the only full-size collective is int8
    txt = jax.jit(shard_map(rs, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data"))
                  ).lower(g_local).compile().as_text()
    a2a = [l for l in txt.splitlines() if "all-to-all" in l
           and "s8[" in l]
    big_f32 = [l for l in txt.splitlines()
               if ("all-to-all" in l or "all-gather" in l)
               and "f32[8,1024]" in l]
    assert a2a, "int8 all-to-all missing from compiled HLO"
    assert not big_f32, "full-size f32 collective leaked onto the wire"

    # ---- compressed_mean matches exact within quant tolerance
    def cm(g):
        return compressed_mean(g[0], ("data",))
    out2 = shard_map(cm, mesh=mesh, in_specs=P("data"),
                         out_specs=P())(g_local)
    err2 = np.abs(np.asarray(out2) - want)
    assert err2.max() < max(tol, 0.05), ("mean", err2.max())
    print("MULTIDEV_OK")
""")


def test_multidevice_wire_semantics():
    env = dict(os.environ, PYTHONPATH=_SRC)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _MULTIDEV_PROG],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "MULTIDEV_OK" in res.stdout
