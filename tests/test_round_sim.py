"""Round-synchronous simulator cores: equivalence, vmap, gradients.

The contract under test (see ``jax_sim`` module docstring):

* ``engine="round"`` must track the Python reference simulator within 2%
  on the Fig. 2/3 scenario suite and the event engine tightly on the
  paper's C == L/10 geometry (where rounds are synchronous by
  construction);
* ``engine="scan"`` is the same round step under a fixed trip count —
  identical results to ``round`` when the bound covers the transfer, one
  compile under ``vmap``, and reverse-differentiable in (C, L);
* ``round_allocate`` replays the event core's sequential cursor draws
  exactly (one fused vector op per round).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.autotune import (  # noqa: E402
    _fused_sweep,
    autotune_chunk_params,
    default_grid,
    tune_chunk_params_grad,
)
from repro.core.chunking import ChunkParams  # noqa: E402
from repro.core.jax_alloc import (  # noqa: E402
    ChunkArrays,
    chunk_sizes,
    round_allocate,
)
from repro.core.jax_sim import (  # noqa: E402
    SimConfig,
    _prep,
    resolve_engine,
    simulate_scan_core,
    simulate_transfer,
)
from repro.core.mdtp import MDTPPolicy  # noqa: E402
from repro.core.scenarios import (  # noqa: E402
    GB,
    paper_baseline,
    with_added_latency,
    with_throttled_fastest,
)
from repro.core.simulator import ServerSpec, simulate  # noqa: E402

MB = 1024 * 1024

BW = [50.0 * MB, 30.0 * MB, 10.0 * MB, 80.0 * MB]


def _jax_args(servers):
    bw = [s.bandwidth for s in servers]
    rtt = [s.rtt for s in servers]
    tt = [s.profile[0][0] if s.profile else np.inf for s in servers]
    tb = [s.profile[0][1] if s.profile else s.bandwidth for s in servers]
    return bw, rtt, tt, tb


# -- acceptance: round core vs Python reference on the Fig. 2/3 suite ------

@pytest.mark.parametrize("scenario,size_gb", [
    ("baseline", 1), ("baseline", 4),           # Fig. 2 size ladder
    ("latency", 4),                             # Fig. 3 (paper runs 64 GB;
                                                # 4 GB is past the transient)
    ("throttle", 1), ("throttle", 4),           # Fig. 4
])
def test_round_core_matches_python_fig23_suite(scenario, size_gb):
    """Round-core completion times within 2% of the Python discrete-event
    simulator across the Fig. 2 (baseline sizes), Fig. 3 (added latency)
    and Fig. 4 (throttled fastest) scenarios."""
    servers = paper_baseline(jitter=0.0)
    if scenario == "latency":
        servers = with_added_latency(servers)
    elif scenario == "throttle":
        servers = with_throttled_fastest(servers)
    size = size_gb * GB
    py = simulate(MDTPPolicy(), servers, size, seed=0)
    bw, rtt, tt, tb = _jax_args(servers)
    jx = simulate_transfer(bw, rtt, size, ChunkParams(4 * MB, 40 * MB),
                           throttle_t=tt, throttle_bw=tb, engine="round")
    assert float(jx.total_time) == pytest.approx(py.total_time, rel=0.02)
    assert float(jnp.sum(jx.bytes_per_server)) == pytest.approx(
        size, rel=1e-5)


def test_round_core_latency_rampup_transient_bounded():
    """Heterogeneous RTT is the round assumption's weakest spot (per-round
    durations stop equalizing, so clocks drift): even on a short 1 GB
    transfer, where the ramp-up transient is least amortized, the error
    stays under 3%."""
    servers = with_added_latency(paper_baseline(jitter=0.0))
    py = simulate(MDTPPolicy(), servers, 1 * GB, seed=0)
    bw, rtt, tt, tb = _jax_args(servers)
    jx = simulate_transfer(bw, rtt, 1 * GB, ChunkParams(4 * MB, 40 * MB),
                           engine="round")
    assert float(jx.total_time) == pytest.approx(py.total_time, rel=0.03)


def test_round_tracks_event_tightly_on_paper_geometry():
    """On the paper's C == L/10 geometry the round engine reproduces the
    event engine almost exactly (same allocation stream)."""
    for c_mb in (2, 4, 16):
        params = ChunkParams(c_mb * MB, 10 * c_mb * MB)
        ev = simulate_transfer(BW, 0.03, 2 * GB, params, engine="event")
        rd = simulate_transfer(BW, 0.03, 2 * GB, params, engine="round")
        assert float(rd.total_time) == pytest.approx(
            float(ev.total_time), rel=2e-3)
        np.testing.assert_allclose(
            np.asarray(rd.bytes_per_server), np.asarray(ev.bytes_per_server),
            rtol=0.02, atol=float(params.large_chunk))
        # one request per server per round in both engines
        np.testing.assert_array_equal(
            np.asarray(rd.requests_per_server),
            np.asarray(ev.requests_per_server))
        # the whole point: O(#rounds) trip count, not O(#chunks)
        assert int(rd.iters) * len(BW) <= int(ev.iters) + len(BW)


def test_round_engine_iters_drop_by_n():
    """Trip count drops ~N-fold: the perf claim's mechanical basis."""
    n = 8
    bw = [(10.0 + 7 * i) * MB for i in range(n)]
    ev = simulate_transfer(bw, 0.03, 1 * GB, ChunkParams(4 * MB, 40 * MB),
                           engine="event")
    rd = simulate_transfer(bw, 0.03, 1 * GB, ChunkParams(4 * MB, 40 * MB),
                           engine="round")
    assert int(ev.iters) >= (n - 1) * int(rd.iters)


def test_randomized_round_vs_event_agreement():
    """Seeded random scenarios (paper-plausible L = 10C geometry): round
    and event engines agree within tolerance; runs without hypothesis."""
    rng = np.random.default_rng(7)
    for _ in range(12):
        n = int(rng.integers(2, 9))
        bw = rng.uniform(2.0, 100.0, size=n) * MB
        size = int(rng.integers(32, 512)) * MB
        c = int(rng.integers(1, 9)) * MB
        params = ChunkParams(c, 10 * c)
        ev = simulate_transfer(bw, 0.02, size, params, engine="event")
        rd = simulate_transfer(bw, 0.02, size, params, engine="round")
        assert float(rd.total_time) == pytest.approx(
            float(ev.total_time), rel=0.03), (n, bw, size, c)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        rates=st.lists(st.floats(min_value=2.0, max_value=100.0),
                       min_size=2, max_size=8),
        size_mb=st.integers(min_value=32, max_value=512),
        c_mb=st.integers(min_value=1, max_value=8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_round_vs_event(rates, size_mb, c_mb, seed):
        """Hypothesis property: for ANY scenario on the paper geometry the
        two engines' totals agree within tolerance and both deliver the
        whole file."""
        params = ChunkParams(c_mb * MB, 10 * c_mb * MB)
        bw = [r * MB for r in rates]
        ev = simulate_transfer(bw, 0.02, size_mb * MB, params, seed=seed,
                               engine="event")
        rd = simulate_transfer(bw, 0.02, size_mb * MB, params, seed=seed,
                               engine="round")
        assert float(rd.total_time) == pytest.approx(
            float(ev.total_time), rel=0.03)
        assert float(jnp.sum(rd.bytes_per_server)) == pytest.approx(
            size_mb * MB, rel=1e-5)
except ImportError:  # hypothesis not installed: seeded test above covers it
    pass


# -- scan engine: equivalence, vmap compile count, differentiability -------

def test_scan_matches_round_when_bound_covers():
    """The scan engine is the same round step under a fixed trip count —
    bit-identical totals when max_rounds covers the transfer."""
    for seed in (0, 3):
        cfg = SimConfig(jitter=0.1, max_rounds=128)
        rd = simulate_transfer(BW, 0.03, 1 * GB, ChunkParams(4 * MB, 40 * MB),
                               seed=seed, config=cfg, engine="round")
        sc = simulate_transfer(BW, 0.03, 1 * GB, ChunkParams(4 * MB, 40 * MB),
                               seed=seed, config=cfg, engine="scan")
        assert float(sc.total_time) == float(rd.total_time)
        np.testing.assert_array_equal(np.asarray(sc.bytes_per_server),
                                      np.asarray(rd.bytes_per_server))
        assert int(sc.iters) == int(rd.iters)


def test_scan_fused_sweep_single_compile_under_vmap():
    """Compile-count guard: the scan engine's fused (C, L) × seed sweep is
    ONE executable for arbitrary grid values (chunk geometry stays traced
    under the double vmap)."""
    jax.clear_caches()
    bw, rtt, tt, tb = _prep(BW, 0.03, None, None)
    cfg = SimConfig(max_rounds=256)
    grid = [(c * MB, l * MB) for c in (2, 4, 8) for l in (20, 40)]

    def run(grid, file_gb):
        gc = jnp.asarray([c for c, _ in grid], jnp.float32)
        gl = jnp.asarray([l for _, l in grid], jnp.float32)
        gm = jnp.full((len(grid),), 64 * 1024, jnp.float32)
        return _fused_sweep(bw, rtt, tt, tb, jnp.float32(file_gb * GB),
                            gc, gl, gm, jnp.arange(2),
                            mode="proportional", config=cfg, engine="scan")

    assert _fused_sweep._cache_size() == 0
    run(grid, 1)
    assert _fused_sweep._cache_size() == 1
    run([(2 * c, 2 * l) for c, l in grid], 2)   # new values, same shapes
    assert _fused_sweep._cache_size() == 1


def test_truncated_simulation_reports_inf_not_fast():
    """An exhausted iteration bound must not masquerade as a fast
    transfer: total_time is +inf when connections are still live."""
    params = ChunkParams(4 * MB, 40 * MB)
    # scan bound far too small for 1 GB at L=40MB (needs ~13 rounds)
    sc = simulate_transfer(BW, 0.03, 1 * GB, params,
                           config=SimConfig(max_rounds=4), engine="scan")
    assert np.isinf(float(sc.total_time))
    assert float(jnp.sum(sc.bytes_per_server)) < 1 * GB
    # same contract on the while engines' max_iters cap
    ev = simulate_transfer(BW, 0.03, 1 * GB, params,
                           config=SimConfig(max_iters=3), engine="event")
    assert np.isinf(float(ev.total_time))
    # a covering bound still reports the true finite time
    ok = simulate_transfer(BW, 0.03, 1 * GB, params,
                           config=SimConfig(max_rounds=64), engine="scan")
    assert np.isfinite(float(ok.total_time))


def test_scan_grad_finite_nonzero():
    """Acceptance: ``jax.grad`` of scan-core total time w.r.t. (C, L) is
    finite and nonzero on a default scenario (continuous relaxation)."""
    bw, rtt, tt, tb = _prep(BW, 0.03, None, None)
    cfg = SimConfig(max_rounds=256, exact_sizes=False)

    def total_time(cl):
        chunk = ChunkArrays(cl[0], cl[1], jnp.float32(64 * 1024))
        return simulate_scan_core(
            bw, rtt, tt, tb, 0, chunk, jnp.float32(1 * GB),
            mode="proportional", config=cfg).total_time

    cl0 = jnp.asarray([4 * MB, 40 * MB], jnp.float32)
    t0 = total_time(cl0)
    g = jax.grad(total_time)(cl0)
    assert np.isfinite(float(t0)) and float(t0) > 0.0
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.any(np.asarray(g) != 0.0)
    # the L-gradient must reflect the within-basin slope: finite-difference
    # agreement at small perturbation
    h = 256.0
    fd = (float(total_time(cl0 + jnp.asarray([0.0, h]))) - float(t0)) / h
    assert float(g[1]) == pytest.approx(fd, rel=0.3, abs=1e-10)


def test_grad_tuner_polish_never_worse_than_grid():
    """The gradient tuner seeds from the fused grid winner and its
    best-seen tracking guarantees it never regresses; gradients at the
    adopted point are finite."""
    grid = default_grid()[:8]
    seed_res = autotune_chunk_params(BW, 0.03, 512 * MB, grid=grid)
    res = tune_chunk_params_grad(
        BW, 0.03, 512 * MB,
        init=(seed_res.params.initial_chunk, seed_res.params.large_chunk),
        steps=10, max_rounds=256)
    assert res.steps == 10
    assert all(np.isfinite(t) for t in res.loss_history)
    assert np.all(np.isfinite(res.final_grad))
    # continuous-relaxation loss at the adopted point can't be worse than
    # at the grid winner (best-seen tracking)
    assert min(res.loss_history) <= res.loss_history[0] + 1e-6
    assert res.params.large_chunk >= res.params.min_chunk


# -- engine routing / allocation unit tests --------------------------------

def test_resolve_engine_routing():
    assert resolve_engine(None, "proportional") == "round"
    assert resolve_engine("auto", "fast_get_large") == "round"
    assert resolve_engine(None, "static") == "event"
    assert resolve_engine("scan", "static") == "scan"
    with pytest.raises(ValueError):
        resolve_engine("warp", "proportional")


def test_static_mode_autotune_routes_to_event():
    """mode="static" sweeps must not silently use the round approximation
    (fixed chunks are not round-synchronous)."""
    grid = default_grid()[:4]
    auto = autotune_chunk_params(BW, 0.03, 256 * MB, grid=grid,
                                 mode="static")
    event = autotune_chunk_params(BW, 0.03, 256 * MB, grid=grid,
                                  mode="static", engine="event")
    np.testing.assert_array_equal(auto.predicted_times, event.predicted_times)


def test_round_allocate_replays_sequential_draws():
    """round_allocate == the event core's per-draw loop: same grants in
    ask order, including the endgame clamp and stable tie-breaking."""
    params = ChunkParams(4 * MB, 40 * MB)
    rng = np.random.default_rng(3)
    for _ in range(20):
        n = int(rng.integers(2, 9))
        th = np.where(rng.random(n) < 0.3, 0.0,
                      rng.uniform(1.0, 90.0, size=n)) * MB
        remaining = float(rng.integers(0, 200 * MB))
        order_key = rng.choice([0.0, 1.5, 2.5], size=n)  # ties likely

        granted, total = round_allocate(
            jnp.asarray(th, jnp.float32), jnp.float32(remaining),
            jnp.asarray(order_key, jnp.float32), params)
        granted = np.asarray(granted, np.float64)

        # reference: draw per server in (order_key, index) order, shrinking
        # the shared remaining after each grant — the event core's loop
        expect = np.zeros(n)
        rem = remaining
        for i in sorted(range(n), key=lambda i: (order_key[i], i)):
            s = float(chunk_sizes(jnp.asarray(th, jnp.float32),
                                  jnp.float32(rem), params)[i])
            expect[i] = s
            rem -= s
        # float32 prefix sums: one ulp at the 200 MB budget scale is 16
        # bytes, surfacing on the final clamped element
        np.testing.assert_allclose(granted, expect, atol=64.0)
        assert float(total) == pytest.approx(expect.sum(), abs=64.0)
