"""Discrete-event simulator + policy behavior tests (paper §VI/§VII)."""

import numpy as np
import pytest

from repro.core import (
    Aria2Policy,
    BitTorrentPolicy,
    MDTPPolicy,
    StaticChunkingPolicy,
    simulate,
)
from repro.core.simulator import ServerSpec
from repro.core.scenarios import (
    GB,
    MBPS,
    bittorrent_seeders,
    paper_balanced,
    paper_baseline,
    with_added_latency,
    with_throttled_fastest,
)

MB = 1024 * 1024
SMALL = 256 * MB  # keep tests fast


def _mk(rates, **kw):
    return [
        ServerSpec(name=f"s{i}", bandwidth=r * MBPS, rtt=kw.pop("rtt", 0.02), **kw)
        for i, r in enumerate(rates)
    ]


@pytest.mark.parametrize(
    "policy_cls", [MDTPPolicy, StaticChunkingPolicy, Aria2Policy, BitTorrentPolicy]
)
def test_integrity_every_byte_once(policy_cls):
    r = simulate(policy_cls(), _mk([5, 10, 20, 40]), SMALL, seed=7)
    r.check_integrity()
    assert sum(r.bytes_per_server) == SMALL


def test_deterministic_given_seed():
    a = simulate(MDTPPolicy(), paper_baseline(), SMALL, seed=3)
    b = simulate(MDTPPolicy(), paper_baseline(), SMALL, seed=3)
    assert a.total_time == b.total_time
    assert a.bytes_per_server == b.bytes_per_server


def test_cannot_beat_aggregate_capacity():
    servers = _mk([10, 20, 30])
    r = simulate(MDTPPolicy(), servers, SMALL, seed=0)
    lower_bound = SMALL / sum(s.bandwidth for s in servers)
    assert r.total_time >= lower_bound * 0.999


def test_single_server_degenerates_to_sequential():
    """One replica: time ~= size/bw + per-chunk RTTs (queuing Model B)."""
    servers = _mk([10], rtt=0.0)
    r = simulate(MDTPPolicy(), servers, SMALL, seed=0)
    assert r.total_time == pytest.approx(SMALL / (10 * MBPS), rel=1e-6)


def test_piecewise_bandwidth_profile():
    """A throttle mid-transfer must slow the finish in a predictable way."""
    # 10 MiB/s for 5 s, then 5 MiB/s. 100 MiB transfer, rtt=0.
    spec = ServerSpec(name="s", bandwidth=10 * MBPS, rtt=0.0,
                      profile=((5.0, 5 * MBPS),))
    r = simulate(StaticChunkingPolicy(chunk_size=100 * MB), [spec], 100 * MB)
    # 50 MiB in first 5 s, remaining 50 MiB at 5 MiB/s = 10 s -> 15 s total
    assert r.total_time == pytest.approx(15.0, rel=1e-6)


def test_server_failure_is_tolerated_and_bytes_conserved():
    servers = [
        ServerSpec(name="dies", bandwidth=30 * MBPS, rtt=0.01, fail_at=2.0),
        ServerSpec(name="ok1", bandwidth=10 * MBPS, rtt=0.01),
        ServerSpec(name="ok2", bandwidth=10 * MBPS, rtt=0.01),
    ]
    r = simulate(MDTPPolicy(), servers, SMALL, seed=1)
    r.check_integrity()
    assert sum(r.bytes_per_server) == SMALL
    # the dead server delivered only what it could before t=2
    assert r.bytes_per_server[0] <= 30 * MBPS * 2.0
    # and was marked dead: no request *started* after the failure
    late = [c for c in r.chunks if c.server == 0 and c.t_request > 2.0]
    assert late == []


def test_all_servers_fail_raises():
    servers = [ServerSpec(name="a", bandwidth=10 * MBPS, fail_at=1.0)]
    with pytest.raises(RuntimeError):
        simulate(MDTPPolicy(), servers, SMALL, seed=0)


def test_mdtp_retry_after_recovers_capacity():
    """With retry enabled, a transiently-down server rejoins the pool."""
    servers = [
        ServerSpec(name="flappy", bandwidth=40 * MBPS, rtt=0.01,
                   avail_up=2.0, avail_down=1.0),
        ServerSpec(name="steady", bandwidth=10 * MBPS, rtt=0.01),
    ]
    for seed in range(20):
        no_retry = simulate(MDTPPolicy(), servers, SMALL, seed=seed)
        if not any(c.truncated for c in no_retry.chunks):
            continue  # flappy never flapped on this seed; try another
        retry = simulate(MDTPPolicy(retry_after=0.5), servers, SMALL, seed=seed)
        retry.check_integrity()
        # rejoining the fast flappy server must help
        assert retry.total_time < no_retry.total_time
        assert retry.bytes_per_server[0] > no_retry.bytes_per_server[0]
        return
    pytest.fail("no seed produced a mid-transfer flap; recalibrate test")


def test_mdtp_completion_spread_beats_static_small_chunks():
    """Bin-packing goal: all replicas finish ~together (paper §IV-B)."""
    servers = _mk([5, 10, 20, 60])
    mdtp = simulate(MDTPPolicy(), servers, SMALL, seed=2)
    static = simulate(StaticChunkingPolicy(chunk_size=16 * MB), servers, SMALL, seed=2)
    assert mdtp.completion_spread() <= static.completion_spread() + 1e-9


def test_mdtp_load_proportional_to_capacity():
    servers = _mk([10, 20, 40])
    r = simulate(MDTPPolicy(), servers, 2 * SMALL, seed=0)
    shares = np.array(r.bytes_per_server) / (2 * SMALL)
    expect = np.array([10, 20, 40]) / 70
    np.testing.assert_allclose(shares, expect, atol=0.05)


def test_mdtp_equal_request_counts_balanced_servers():
    """Paper Fig. 5c: near-equal replicas -> equal request counts."""
    r = simulate(MDTPPolicy(), paper_balanced(jitter=0.0), 8 * GB, seed=0)
    counts = r.requests_per_server
    assert max(counts) - min(counts) <= 2


def test_aria2_uses_5_of_6_replicas():
    """Paper Fig. 5a: Aria2 at 83% utilization, slowest parked."""
    r = simulate(Aria2Policy(), paper_baseline(jitter=0.0), 4 * GB, seed=0)
    assert r.utilization(min_frac=0.01) == pytest.approx(5 / 6)
    slowest = int(np.argmin([s.bandwidth for s in paper_baseline()]))
    assert r.bytes_per_server[slowest] < 0.01 * 4 * GB


def test_aria2_overloads_fastest(rng_seed=0):
    """Paper Fig. 5b: most packets go to the fastest replica."""
    r = simulate(Aria2Policy(), paper_baseline(jitter=0.0), 4 * GB, seed=rng_seed)
    fastest = int(np.argmax([s.bandwidth for s in paper_baseline()]))
    assert int(np.argmax(r.packets_per_server)) == fastest


def test_mdtp_beats_aria2_paper_band():
    """Paper §VII-B: 10-22% improvement over Aria2 across file sizes."""
    servers = paper_baseline()
    for size in (1 * GB, 4 * GB):
        t_mdtp = simulate(MDTPPolicy(), servers, size, seed=11).total_time
        t_aria = simulate(Aria2Policy(), servers, size, seed=11).total_time
        gain = (t_aria - t_mdtp) / t_aria
        assert 0.05 <= gain <= 0.30, f"{size}: gain {gain:.2%} out of band"


def test_bittorrent_slower_and_noisier():
    """Paper Fig. 2a: BT ~2x slower with far higher variance.

    The gap comes from seeder-availability gaps, which need transfers long
    enough for flaps to accumulate (the paper's clearest gap is at 32/64 GB;
    4 GB keeps the test fast while well past the flap timescale)."""
    times_bt, times_mdtp = [], []
    for seed in range(4):
        times_bt.append(
            simulate(BitTorrentPolicy(), bittorrent_seeders(), 4 * GB,
                     seed=seed).total_time
        )
        times_mdtp.append(
            simulate(MDTPPolicy(), paper_baseline(), 4 * GB, seed=seed).total_time
        )
    assert np.mean(times_bt) > 1.5 * np.mean(times_mdtp)
    assert np.std(times_bt) > 3 * np.std(times_mdtp)


def test_added_latency_hurts_mdtp_least():
    """Paper Fig. 3: MDTP adapts to +0.5 s latency on the fastest server."""
    base, lat = paper_baseline(jitter=0.0), with_added_latency(paper_baseline(jitter=0.0))
    deltas = {}
    for cls in (MDTPPolicy, Aria2Policy):
        t0 = simulate(cls(), base, 4 * GB, seed=0).total_time
        t1 = simulate(cls(), lat, 4 * GB, seed=0).total_time
        deltas[cls().name] = t1 - t0
    assert deltas["mdtp"] < deltas["aria2"]


def test_throttle_hurts_mdtp_least():
    """Paper Fig. 4: throttling the fastest replica to 500 Mbps."""
    base = paper_baseline(jitter=0.0)
    thr = with_throttled_fastest(base)
    d = {}
    for cls in (MDTPPolicy, Aria2Policy):
        t0 = simulate(cls(), base, 4 * GB, seed=0).total_time
        t1 = simulate(cls(), thr, 4 * GB, seed=0).total_time
        d[cls().name] = t1 - t0
    assert d["mdtp"] > 0  # throttle must bite
    assert d["mdtp"] <= d["aria2"] + 1e-6
