"""The sans-I/O scheduling core: purity, decision parity, one source of
truth for the tuning constants.

Three contracts from the extraction:

* **Import purity** — ``repro.transfer.sched`` loads with no event loop,
  no sockets, no JAX (checked in a subprocess so this test's own
  imports can't mask a violation; ``tools/layercheck.py`` enforces the
  same statically).
* **Decision parity** — a real-socket ``MDTPClient.fetch`` records its
  scheduler's decision trace; replaying the identical event stream
  through a bare ``ChunkScheduler`` (no client, no loop) reproduces
  every assignment/commit/repool/hedge decision exactly.  This is what
  makes the extraction an extraction and not a fork.
* **Defaults consolidation** — ``client.py`` and ``manager.py`` read
  their endgame/hedge/probation constants from ``sched.defaults``
  instead of re-stating the numbers (the threshold-drift fix).
"""

import asyncio
import inspect
import subprocess
import sys

from repro.core.chunking import ChunkParams
from repro.transfer import sched
from repro.transfer.client import (DEFAULT_PIPELINE_DEPTH, ClientOptions,
                                   MDTPClient, Replica)
from repro.transfer.manager import FleetModel, TransferManager
from repro.transfer.sched import ChunkScheduler, defaults, replay
from repro.transfer.server import RangeServer, Throttle

KB = 1024


def _blob(n: int, seed: int = 7) -> bytes:
    out = bytearray(n)
    x = seed
    for i in range(n):
        x = (x * 1103515245 + 12345) & 0x7FFFFFFF
        out[i] = x & 0xFF
    return bytes(out)


# --------------------------------------------------------------------------
# import purity
# --------------------------------------------------------------------------

def test_sched_imports_without_io_or_jax():
    code = (
        "import sys\n"
        "import repro.transfer.sched as s\n"
        "bad = [m for m in ('asyncio', 'socket', 'jax', 'jaxlib')\n"
        "       if m in sys.modules]\n"
        "assert not bad, f'sans-I/O core dragged in {bad}'\n"
        "assert s.ChunkScheduler is not None\n"
        "loaded = sorted(m for m in sys.modules if m.startswith('repro'))\n"
        "print(' '.join(loaded))\n"
    )
    res = subprocess.run([sys.executable, "-c", code],
                        capture_output=True, text=True, timeout=60,
                        env={"PYTHONPATH": "src", "PATH": "/usr/bin"},
                        cwd=None)
    assert res.returncode == 0, res.stdout + res.stderr
    # the import closure stays small: core.chunking + transfer.journal
    # are the only non-sched repro modules the state machine needs
    for mod in res.stdout.split():
        assert mod.startswith(("repro.transfer.sched", "repro.transfer",
                               "repro.core", "repro")), mod
        assert "jax" not in mod


# --------------------------------------------------------------------------
# decision parity (record on the wire, replay sans-I/O)
# --------------------------------------------------------------------------

def _record_fetch(*, hedge_quantile=0.0, size=192 * KB, n_srv=3,
                  rates=(4096 * KB, 1024 * KB, 512 * KB)):
    """Fetch over real sockets with a recording scheduler; return the
    trace plus everything a bare re-construction needs."""
    blob = _blob(size)
    servers = []
    for r in rates[:n_srv]:
        srv = RangeServer(throttle=Throttle(bytes_per_s=r))
        srv.add_blob("/data", blob)
        srv.start()
        servers.append(srv)
    reps = [Replica("127.0.0.1", s.port, "/data") for s in servers]
    params = ChunkParams(16 * KB, 32 * KB, min_chunk=4 * KB)
    client = MDTPClient(reps, params=params,
                        hedge_quantile=hedge_quantile)
    client._sched_trace = trace = []
    try:
        buf, report = asyncio.run(client.fetch(size))
    finally:
        for s in servers:
            s.stop()
    assert bytes(buf) == blob
    cfg = dict(size=size, mirrors=[False] * len(reps), params=params,
               depth=client.pipeline_depth,
               hedge_quantile=hedge_quantile,
               hedge_waste_frac=client.hedge_waste_frac,
               default_rtt=MDTPClient.DEFAULT_RTT,
               max_failures=client.max_failures,
               coverage_refresh_s=client.coverage_refresh_s)
    return trace, cfg, report


def test_decision_parity_plain():
    trace, cfg, _ = _record_fetch()
    assert any(ev[0] == "on_assign" for ev in trace)
    mismatches = replay(trace, lambda clock: ChunkScheduler(
        cfg["size"], cfg["mirrors"], params=cfg["params"],
        depth=cfg["depth"], hedge_quantile=cfg["hedge_quantile"],
        hedge_waste_frac=cfg["hedge_waste_frac"],
        default_rtt=cfg["default_rtt"],
        max_failures=cfg["max_failures"],
        coverage_refresh_s=cfg["coverage_refresh_s"], clock=clock))
    assert mismatches == [], mismatches[:5]


def test_decision_parity_hedged():
    # hedging exercises pick_hedge/outstanding/observe_latency paths;
    # the slow third replica makes endgame hedges plausible but parity
    # must hold whether or not any fired
    trace, cfg, report = _record_fetch(hedge_quantile=0.95)
    mismatches = replay(trace, lambda clock: ChunkScheduler(
        cfg["size"], cfg["mirrors"], params=cfg["params"],
        depth=cfg["depth"], hedge_quantile=cfg["hedge_quantile"],
        hedge_waste_frac=cfg["hedge_waste_frac"],
        default_rtt=cfg["default_rtt"],
        max_failures=cfg["max_failures"],
        coverage_refresh_s=cfg["coverage_refresh_s"], clock=clock))
    assert mismatches == [], mismatches[:5]
    assert report.hedge_wasted_bytes <= \
        cfg["hedge_waste_frac"] * cfg["size"]


def test_replay_detects_divergence():
    # the harness itself must be falsifiable: replaying against a
    # scheduler configured differently (other chunk geometry) must
    # surface mismatches, not vacuously pass
    trace, cfg, _ = _record_fetch()
    other = ChunkParams(32 * KB, 64 * KB, min_chunk=8 * KB)
    mismatches = replay(trace, lambda clock: ChunkScheduler(
        cfg["size"], cfg["mirrors"], params=other,
        depth=cfg["depth"], default_rtt=cfg["default_rtt"],
        max_failures=cfg["max_failures"],
        coverage_refresh_s=cfg["coverage_refresh_s"], clock=clock))
    assert mismatches


# --------------------------------------------------------------------------
# bare-scheduler behavior (no sockets at all)
# --------------------------------------------------------------------------

def test_bare_scheduler_drains_pool():
    t = [0.0]
    s = ChunkScheduler(64 * KB, [False, False],
                       params=ChunkParams(8 * KB, 16 * KB,
                                          min_chunk=4 * KB),
                       clock=lambda: t[0])
    tp = [1e6, 1e6]
    landed = 0
    while s.remaining > 0 or s.inflight > 0:
        progressed = False
        for i in range(2):
            if s.remaining <= 0 or not s.can_draw(i):
                continue
            want = s.next_want(i, tp)
            asn = s.on_assign(i, want)
            if asn is None:
                continue
            t[0] += 0.01
            res = s.on_commit(i, asn.start, asn.length, asn.ban,
                              asn.length)
            assert not res.settled_won
            landed += asn.length
            progressed = True
        assert progressed, "scheduler wedged with work remaining"
    assert landed == 64 * KB
    assert s.finished and s.done_bytes == 64 * KB


def test_bare_scheduler_reclaim_and_ban():
    t = [0.0]
    s = ChunkScheduler(32 * KB, [False, False],
                       params=ChunkParams(8 * KB, 16 * KB,
                                          min_chunk=4 * KB),
                       clock=lambda: t[0])
    asn = s.on_assign(0, s.next_want(0, [1e6, 1e6]))
    res = s.on_reclaim(asn.start, asn.length, frozenset({0}), count=True)
    assert not res.settled
    assert s.refetched == 1
    # the banned replica cannot re-draw the reclaimed range while the
    # other one can
    asn2 = s.on_assign(1, s.next_want(1, [1e6, 1e6]))
    assert asn2 is not None


# --------------------------------------------------------------------------
# defaults consolidation (the threshold-drift fix)
# --------------------------------------------------------------------------

def test_client_reads_sched_defaults():
    assert DEFAULT_PIPELINE_DEPTH == defaults.PIPELINE_DEPTH
    assert MDTPClient.DEFAULT_RTT == defaults.DEFAULT_RTT
    assert MDTPClient.OBS_WINDOW_S == defaults.OBS_WINDOW_S
    assert ClientOptions.hedge_waste_frac == defaults.HEDGE_WASTE_FRAC


def test_manager_reads_sched_defaults():
    fm = inspect.signature(FleetModel.__init__).parameters
    assert fm["probation_health"].default == defaults.PROBATION_HEALTH
    assert fm["probation_retry_limit"].default == \
        defaults.PROBATION_RETRY_LIMIT
    assert fm["probation_slow_frac"].default == \
        defaults.PROBATION_SLOW_FRAC
    assert fm["probation_strikes"].default == defaults.PROBATION_STRIKES
    assert fm["probation_clean_streak"].default == \
        defaults.PROBATION_CLEAN_STREAK
    assert fm["probation_floor"].default == defaults.PROBATION_FLOOR
    assert fm["readmit_init"].default == defaults.READMIT_INIT
    tm = inspect.signature(TransferManager.__init__).parameters
    assert tm["hedge_quantile"].default == defaults.HEDGE_QUANTILE


def test_scheduler_ctor_reads_sched_defaults():
    s = ChunkScheduler(1024, [False])
    assert s.depth == defaults.PIPELINE_DEPTH
    assert s.hedge_waste_frac == defaults.HEDGE_WASTE_FRAC
    assert sched.defaults is defaults
