"""The offline format gate (``tools/format_check.py``) stays clean.

CI's lint job runs the same script; having it in tier-1 means the tree
cannot drift out of the normalized state between lint runs (and the gate
is enforced even where the lint toolchain isn't installed).
"""

import os
import subprocess
import sys

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_tree_is_format_normalized():
    res = subprocess.run(
        [sys.executable, os.path.join("tools", "format_check.py")],
        capture_output=True, text=True, cwd=_ROOT, timeout=120)
    assert res.returncode == 0, res.stdout + res.stderr


def test_normalize_rules_python():
    sys.path.insert(0, os.path.join(_ROOT, "tools"))
    try:
        from format_check import normalize
    finally:
        sys.path.pop(0)
    assert normalize("a = 1 \nb = 2\t\n") == "a = 1\nb = 2\n"  # trailing ws
    assert normalize("a = 1\r\nb = 2\n") == "a = 1\nb = 2\n"   # CRLF -> LF
    assert normalize("a = 1") == "a = 1\n"             # EOF newline added
    assert normalize("a = 1\n\n\n") == "a = 1\n"       # whitespace tail
    assert normalize("\tx = 1\n") == "    x = 1\n"     # tab indent
    assert normalize("x = '\t'\n") == "x = '\t'\n"     # literal value kept
    assert normalize("") == ""


def test_normalize_protects_literals_and_markdown():
    sys.path.insert(0, os.path.join(_ROOT, "tools"))
    try:
        from format_check import normalize
    finally:
        sys.path.pop(0)
    # every line of a multi-line string literal is verbatim — trailing
    # spaces and tab indentation are part of its VALUE
    lit = 's = """\n\tall:\nkeep  \n"""\n'
    assert normalize(lit) == lit
    fstr = 'x = 1\ns = f"""\n\t{x}  \n"""\n'
    assert normalize(fstr) == fstr
    # ...but code on lines outside the literal span is still normalized
    # (boundary lines are protected whole, trailing content included)
    mixed = 'y = 2  \ns = """\na\t \n"""\nz = 3\t\n'
    assert normalize(mixed) == 'y = 2\ns = """\na\t \n"""\nz = 3\n'
    # a file that does not tokenize is left entirely alone
    broken = "s = '''\nnever closed \n"
    assert normalize(broken) == broken
    # Markdown: two-trailing-space hard breaks and tab-indented fences
    # survive; only the EOF newline is enforced
    md = "line one  \n\tcode\n"
    assert normalize(md, kind=".md") == md
    assert normalize("text", kind=".md") == "text\n"
    assert normalize("text\n\n\n", kind=".md") == "text\n"
