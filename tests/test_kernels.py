"""Pallas kernel validation (interpret mode) vs pure-jnp oracles.

Shape/dtype sweeps per the assignment: every kernel is allclose-checked
against its ref.py across head dims (incl. kimi's 112 -> lane-padding
path), GQA ratios, causal/window combinations and dtypes.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention_ref, flash_attention, rmsnorm, rmsnorm_ref


def _qkv(key, B, Sq, Sk, H, KV, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, Sk, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, Sk, KV, hd), jnp.float32).astype(dtype)
    return q, k, v


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("hd", [64, 112, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_head_dims(hd, dtype):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 256, 256, 4, 2, hd, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("G", [1, 2, 8])
def test_flash_gqa_ratios(G):
    H = 8
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 128, 128, H, H // G, 64,
                   jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [32, 128, 511])
def test_flash_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 512, 512, 4, 1, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_non_causal():
    """Bidirectional (whisper-encoder style)."""
    q, k, v = _qkv(jax.random.PRNGKey(3), 2, 128, 256, 4, 4, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_ragged_seq_padding():
    """Sq=Sk=200 pads to 256-blocks; padded keys must not leak."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 200, 200, 2, 2, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, blk_q=128, blk_k=128,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("blk", [(64, 64), (128, 256), (256, 128)])
def test_flash_block_shape_sweep(blk):
    bq, bk = blk
    q, k, v = _qkv(jax.random.PRNGKey(5), 1, 512, 512, 2, 1, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=True, blk_q=bq, blk_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_custom_scale():
    """gemma3-style attn scale override."""
    q, k, v = _qkv(jax.random.PRNGKey(6), 1, 128, 128, 4, 1, 128, jnp.float32)
    scale = 1.0 / math.sqrt(256.0)
    out = flash_attention(q, k, v, causal=True, scale=scale, interpret=True)
    ref = attention_ref(q, k, v, causal=True, scale=scale)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_matches_model_attention():
    """The kernel agrees with the production XLA attention layer."""
    from repro.configs import reduced_config
    from repro.models.layers import attention
    from repro.models.common import init_params
    from repro.models.transformer import model_specs

    cfg = reduced_config("qwen3-1.7b").replace(qk_norm=False)
    specs = model_specs(cfg)["blocks"]["b0_attn"]["attn"]
    p = init_params(jax.random.PRNGKey(0), specs, jnp.float32)
    p = jax.tree.map(lambda a: a[0], p)  # unstack layer dim
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))

    xla_out = attention(p, cfg, x, causal=True)

    # reproduce q/k/v exactly, then kernel-attend
    import jax.numpy as jnp2
    q = jnp2.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp2.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp2.einsum("bsd,dnh->bsnh", x, p["wv"])
    from repro.models.layers import rope_sin_cos, apply_rope
    pos = jnp2.arange(64, dtype=jnp2.int32)
    sin, cos = rope_sin_cos(pos, cfg.hd, cfg.rope_theta)
    q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    o = flash_attention(q, k, v, causal=True, interpret=True)
    kern_out = jnp2.einsum("bsnh,nhd->bsd", o, p["wo"])
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(xla_out),
                               atol=5e-4, rtol=5e-4)


# ------------------------------------------------------------------ rmsnorm

@pytest.mark.parametrize("shape", [(64, 256), (3, 7, 512), (1000, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_shapes(shape, dtype):
    kx, ks = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, shape, jnp.float32).astype(dtype)
    scale = jax.random.normal(ks, shape[-1:], jnp.float32) * 0.1 + 1.0
    out = rmsnorm(x, scale, interpret=True)
    ref = rmsnorm_ref(x.reshape(-1, shape[-1]), scale).reshape(shape)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_rmsnorm_fused_residual():
    kx, kr = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (128, 256))
    r = jax.random.normal(kr, (128, 256))
    scale = jnp.ones((256,))
    out = rmsnorm(x, scale, residual=r, interpret=True)
    ref = rmsnorm_ref(x, scale, residual=r)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)
