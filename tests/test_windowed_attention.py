"""Sliding-window attention must SKIP out-of-window keys (sliced k/v per
q block) with bit-level equivalence to the masked-full-keys form."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import cost_analysis_dict
from repro.configs import get_config
from repro.models.layers import attention, attention_specs
from repro.models.common import init_params


def _setup(window, S=512, dtype="float32"):
    cfg = get_config("gemma3-1b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        vocab_size=128, attn_window=window, dtype=dtype)
    p = init_params(jax.random.key(0), attention_specs(cfg), cfg.jdtype)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, S, 64)) * 0.3, cfg.jdtype)
    return cfg, p, x


@pytest.mark.parametrize("window", [32, 96, 128])
def test_windowed_slice_equals_masked(window):
    cfg, p, x = _setup(window)
    y_win = attention(p, cfg, x, causal=True, window=window, q_block=128)
    y_ref = attention(p, cfg, x, causal=True, window=window, q_block=512)
    np.testing.assert_allclose(np.asarray(y_win), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)


def test_windowed_slice_fewer_flops():
    """The compiled windowed path must do substantially fewer dot FLOPs
    than the masked-full-keys path (that is the point of the skip)."""
    cfg, p, x = _setup(window=64, S=1024)

    def run(qb):
        return jax.jit(lambda x: attention(
            p, cfg, x, causal=True, window=64, q_block=qb))

    fl_win = cost_analysis_dict(run(128).lower(x).compile())["flops"]
    fl_ref = cost_analysis_dict(run(1024).lower(x).compile())["flops"]
    assert fl_win < fl_ref * 0.5, (fl_win, fl_ref)
