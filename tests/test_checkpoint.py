"""Checkpoint manager: atomicity, GC, async save, multi-source restore,
elastic resharding."""

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.transfer import RangeServer, Replica, Throttle

MB = 1024 * 1024


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 64)),
                   "b": jnp.arange(64, dtype=jnp.float32)},
        "opt": {"m": jnp.zeros((64, 64)), "step": jnp.float32(7)},
        "step": jnp.int32(42),
    }


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 100, state)
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 100
    assert _trees_equal(state, restored)


def test_incomplete_checkpoint_ignored(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 100, state)
    # simulate a crash: newer dir without manifest
    crashed = tmp_path / "step_0000000200"
    crashed.mkdir()
    (crashed / "data.bin").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 100
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 100


def test_manager_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=10, keep=2,
                            async_save=False)
    state = _state()
    for step in (10, 20, 30, 40):
        assert mgr.maybe_save(step, state)
    assert not mgr.maybe_save(41, state)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [30, 40]


def test_async_save_completes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=1, keep=1,
                            async_save=True)
    mgr.maybe_save(1, _state())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_multi_source_restore(tmp_path):
    """Restore via MDTP from three throttled mirrors; bytes identical."""
    state = {"params": {"w": jax.random.normal(jax.random.PRNGKey(1),
                                               (512, 512))},
             "step": jnp.int32(5)}
    d = save_checkpoint(str(tmp_path), 300, state)

    servers = []
    for bw in (20 * MB, 40 * MB, 80 * MB):
        s = RangeServer(throttle=Throttle(bytes_per_s=bw)).start()
        base = "/ckpt/step_0000000300"
        s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
        s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
        servers.append(s)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/ckpt") for s in servers]
        restored, step = restore_checkpoint(
            str(tmp_path), state, step=300, replicas=replicas)
        assert step == 300
        assert _trees_equal(state, restored)
    finally:
        for s in servers:
            s.stop()


def test_multi_source_restore_survives_mirror_death(tmp_path):
    state = {"params": {"w": jnp.ones((1024, 1024), jnp.float32)},
             "step": jnp.int32(1)}
    d = save_checkpoint(str(tmp_path), 7, state)
    victim = RangeServer(throttle=Throttle(bytes_per_s=2 * MB)).start()
    healthy = RangeServer(throttle=Throttle(bytes_per_s=50 * MB)).start()
    for s in (victim, healthy):
        base = "/ckpt/step_0000000007"
        s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
        s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
    try:
        threading.Timer(0.1, victim.stop).start()
        replicas = [Replica("127.0.0.1", victim.port, "/ckpt"),
                    Replica("127.0.0.1", healthy.port, "/ckpt")]
        restored, step = restore_checkpoint(str(tmp_path), state, step=7,
                                            replicas=replicas)
        assert _trees_equal(state, restored)
    finally:
        healthy.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_streaming_restore_materializes_leaves_incrementally(tmp_path):
    """The replica restore path streams: each leaf is device_put the
    moment its byte range completes, out-of-order and split deliveries
    included — exercised directly against the sink."""
    from repro.checkpoint.manager import _StreamingRestore, _MANIFEST, _DATA

    state = {"a": jnp.arange(1000, dtype=jnp.float32),
             "b": jnp.ones((3, 7), jnp.int32),
             "c": jnp.float32(2.5)}
    d = save_checkpoint(str(tmp_path), 1, state)
    manifest = json.load(open(os.path.join(d, _MANIFEST)))
    blob = open(os.path.join(d, _DATA), "rb").read()

    stream = _StreamingRestore(manifest, state)
    with pytest.raises(IOError):
        stream.finish()                      # nothing delivered yet
    # deliver in reverse order, split mid-leaf and across leaf boundaries
    n = len(blob)
    cuts = [0, 100, 1000, 2500, n]
    pieces = [(cuts[i], blob[cuts[i]:cuts[i + 1]])
              for i in range(len(cuts) - 1)]
    for start, data in reversed(pieces):
        stream.sink(start, data)
    restored = stream.finish()
    assert _trees_equal(state, restored)


def test_streaming_restore_tolerates_overlapping_duplicates(tmp_path):
    """Overlapping / repeated range deliveries (a retried wave, a
    speculative re-fetch) must not double-count leaf bytes: countdowns
    stay exact, every leaf materializes exactly once, finish() succeeds."""
    from repro.checkpoint.manager import _StreamingRestore, _MANIFEST, _DATA

    state = {"a": jnp.arange(1000, dtype=jnp.float32),
             "b": jnp.ones((3, 7), jnp.int32),
             "c": jnp.float32(2.5)}
    d = save_checkpoint(str(tmp_path), 1, state)
    manifest = json.load(open(os.path.join(d, _MANIFEST)))
    blob = open(os.path.join(d, _DATA), "rb").read()
    n = len(blob)

    stream = _StreamingRestore(manifest, state)
    # exact duplicate of a mid-blob range, delivered twice
    stream.sink(100, blob[100:1000])
    stream.sink(100, blob[100:1000])
    # partial overlaps on both sides, one spanning a leaf boundary
    stream.sink(0, blob[0:500])
    stream.sink(800, blob[800:4020])
    # duplicate covering everything seen so far plus the tail
    stream.sink(0, blob)
    restored = stream.finish()
    assert _trees_equal(state, restored)
    assert stream.duplicate_bytes > 0
    # countdowns never went negative (finish() already proves == 0, but
    # assert the accounting is visible)
    assert all(r == 0 for r in stream._remaining)

    # zero-length and fully-duplicate deliveries after completion are no-ops
    stream.sink(0, b"")
    stream.sink(0, blob[0:64])
    assert _trees_equal(state, stream.finish())


def test_multi_source_restore_waves_retune(tmp_path):
    """Wave-split restore: the blob arrives in several offset fetches with
    a grid re-tune between waves; bytes still land exactly once each."""
    state = {"params": {"w": jax.random.normal(jax.random.PRNGKey(4),
                                               (512, 512)),
                        "b": jnp.arange(128, dtype=jnp.float32)},
             "step": jnp.int32(9)}
    d = save_checkpoint(str(tmp_path), 400, state)
    servers = []
    for bw in (30 * MB, 60 * MB):
        s = RangeServer(throttle=Throttle(bytes_per_s=bw)).start()
        base = "/ckpt/step_0000000400"
        s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
        s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
        servers.append(s)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/ckpt") for s in servers]
        total = os.path.getsize(os.path.join(d, "data.bin"))
        restored, step = restore_checkpoint(
            str(tmp_path), state, step=400, replicas=replicas,
            wave_bytes=total // 3 + 1)
        assert step == 400
        assert _trees_equal(state, restored)
    finally:
        for s in servers:
            s.stop()


def test_multi_source_restore_waves_with_online_tuner(tmp_path):
    """An online tuner rides the wave loop via the client's telemetry
    hook; restore correctness is unaffected by mid-wave param swaps."""
    from repro.core.chunking import ChunkParams

    class ScriptedTuner:
        def __init__(self):
            self.calls = 0

        def update(self, t):
            self.calls += 1
            return ChunkParams(initial_chunk=64 * 1024,
                               large_chunk=256 * 1024)

    state = {"w": jax.random.normal(jax.random.PRNGKey(5), (700, 700))}
    d = save_checkpoint(str(tmp_path), 500, state)
    s = RangeServer(throttle=Throttle(bytes_per_s=50 * MB)).start()
    base = "/ckpt/step_0000000500"
    s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
    s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
    try:
        replicas = [Replica("127.0.0.1", s.port, "/ckpt")]
        total = os.path.getsize(os.path.join(d, "data.bin"))
        tuner = ScriptedTuner()
        restored, _ = restore_checkpoint(
            str(tmp_path), state, step=500, replicas=replicas,
            tuner=tuner, wave_bytes=total // 2 + 1)
        assert _trees_equal(state, restored)
        assert tuner.calls >= 1
    finally:
        s.stop()


def test_multi_source_restore_via_manager(tmp_path):
    """``restore_checkpoint(manager=...)`` rides the shared fleet: the
    manifest and data fetches run as managed transfers (per-replica
    in-flight caps enforced), telemetry lands in the fleet model, and the
    geometry the restore's between-wave re-tune adopts warm-starts the
    manager's next transfer."""
    from repro.core.chunking import ChunkParams
    from repro.transfer import TransferManager

    state = {"w": jax.random.normal(jax.random.PRNGKey(6), (600, 600))}
    d = save_checkpoint(str(tmp_path), 600, state)
    servers = []
    for bw in (30 * MB, 60 * MB):
        s = RangeServer(throttle=Throttle(bytes_per_s=bw,
                                          deterministic=True)).start()
        base = "/ckpt/step_0000000600"
        s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
        s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
        servers.append(s)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/ckpt") for s in servers]
        start_params = ChunkParams(initial_chunk=128 * 1024,
                                   large_chunk=512 * 1024)
        mgr = TransferManager(replicas, params=start_params,
                              max_inflight_per_replica=1)
        total = os.path.getsize(os.path.join(d, "data.bin"))
        restored, step = restore_checkpoint(
            str(tmp_path), state, step=600, replicas=replicas,
            manager=mgr, wave_bytes=total // 2 + 1)
        assert step == 600
        assert _trees_equal(state, restored)
        # the fleet model observed both mirrors through the restore
        snap = mgr.snapshot()
        assert {r.name for r in replicas} <= set(snap)
        assert all(v["chunks"] > 0 for v in snap.values())
        # the cap held across the manifest + wave fetches
        for s in servers:
            assert s.peak_concurrent_requests <= 1
        # the between-wave grid re-tune's adoption persisted: the next
        # managed transfer would start from the re-tuned geometry
        assert mgr.params is not None
        assert mgr.params != start_params
    finally:
        for s in servers:
            s.stop()


def test_streaming_restore_respects_shardings(tmp_path):
    """Streamed leaves land with the requested sharding (the H2D overlap
    must not lose the placement contract)."""
    state = {"w": jnp.arange(16 * 16, dtype=jnp.float32).reshape(16, 16)}
    d = save_checkpoint(str(tmp_path), 2, state)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    shardings = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))}

    from repro.checkpoint.manager import _StreamingRestore, _MANIFEST, _DATA
    manifest = json.load(open(os.path.join(d, _MANIFEST)))
    blob = open(os.path.join(d, _DATA), "rb").read()
    stream = _StreamingRestore(manifest, state, shardings)
    stream.sink(0, blob)
    restored = stream.finish()
    assert _trees_equal(state, restored)
    assert restored["w"].sharding.spec == jax.sharding.PartitionSpec(
        "data", "model")


def test_elastic_restore_resharding(tmp_path):
    """Restore with explicit target shardings (single-device 'mesh' here;
    the dry-run exercises the 512-device version of the same call)."""
    state = {"w": jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64)}
    save_checkpoint(str(tmp_path), 11, state)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    shardings = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))}
    restored, _ = restore_checkpoint(str(tmp_path), state,
                                     shardings=shardings)
    assert _trees_equal(state, restored)
    assert restored["w"].sharding.spec == jax.sharding.PartitionSpec(
        "data", "model")
