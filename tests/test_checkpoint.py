"""Checkpoint manager: atomicity, GC, async save, multi-source restore,
elastic resharding."""

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.transfer import RangeServer, Replica, Throttle

MB = 1024 * 1024


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (64, 64)),
                   "b": jnp.arange(64, dtype=jnp.float32)},
        "opt": {"m": jnp.zeros((64, 64)), "step": jnp.float32(7)},
        "step": jnp.int32(42),
    }


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_save_restore_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 100, state)
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 100
    assert _trees_equal(state, restored)


def test_incomplete_checkpoint_ignored(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 100, state)
    # simulate a crash: newer dir without manifest
    crashed = tmp_path / "step_0000000200"
    crashed.mkdir()
    (crashed / "data.bin").write_bytes(b"garbage")
    assert latest_step(str(tmp_path)) == 100
    restored, step = restore_checkpoint(str(tmp_path), state)
    assert step == 100


def test_manager_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=10, keep=2,
                            async_save=False)
    state = _state()
    for step in (10, 20, 30, 40):
        assert mgr.maybe_save(step, state)
    assert not mgr.maybe_save(41, state)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [30, 40]


def test_async_save_completes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every_steps=1, keep=1,
                            async_save=True)
    mgr.maybe_save(1, _state())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_multi_source_restore(tmp_path):
    """Restore via MDTP from three throttled mirrors; bytes identical."""
    state = {"params": {"w": jax.random.normal(jax.random.PRNGKey(1),
                                               (512, 512))},
             "step": jnp.int32(5)}
    d = save_checkpoint(str(tmp_path), 300, state)

    servers = []
    for bw in (20 * MB, 40 * MB, 80 * MB):
        s = RangeServer(throttle=Throttle(bytes_per_s=bw)).start()
        base = "/ckpt/step_0000000300"
        s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
        s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
        servers.append(s)
    try:
        replicas = [Replica("127.0.0.1", s.port, "/ckpt") for s in servers]
        restored, step = restore_checkpoint(
            str(tmp_path), state, step=300, replicas=replicas)
        assert step == 300
        assert _trees_equal(state, restored)
    finally:
        for s in servers:
            s.stop()


def test_multi_source_restore_survives_mirror_death(tmp_path):
    state = {"params": {"w": jnp.ones((1024, 1024), jnp.float32)},
             "step": jnp.int32(1)}
    d = save_checkpoint(str(tmp_path), 7, state)
    victim = RangeServer(throttle=Throttle(bytes_per_s=2 * MB)).start()
    healthy = RangeServer(throttle=Throttle(bytes_per_s=50 * MB)).start()
    for s in (victim, healthy):
        base = "/ckpt/step_0000000007"
        s.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
        s.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
    try:
        threading.Timer(0.1, victim.stop).start()
        replicas = [Replica("127.0.0.1", victim.port, "/ckpt"),
                    Replica("127.0.0.1", healthy.port, "/ckpt")]
        restored, step = restore_checkpoint(str(tmp_path), state, step=7,
                                            replicas=replicas)
        assert _trees_equal(state, restored)
    finally:
        healthy.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_streaming_restore_materializes_leaves_incrementally(tmp_path):
    """The replica restore path streams: each leaf is device_put the
    moment its byte range completes, out-of-order and split deliveries
    included — exercised directly against the sink."""
    from repro.checkpoint.manager import _StreamingRestore, _MANIFEST, _DATA

    state = {"a": jnp.arange(1000, dtype=jnp.float32),
             "b": jnp.ones((3, 7), jnp.int32),
             "c": jnp.float32(2.5)}
    d = save_checkpoint(str(tmp_path), 1, state)
    manifest = json.load(open(os.path.join(d, _MANIFEST)))
    blob = open(os.path.join(d, _DATA), "rb").read()

    stream = _StreamingRestore(manifest, state)
    with pytest.raises(IOError):
        stream.finish()                      # nothing delivered yet
    # deliver in reverse order, split mid-leaf and across leaf boundaries
    n = len(blob)
    cuts = [0, 100, 1000, 2500, n]
    pieces = [(cuts[i], blob[cuts[i]:cuts[i + 1]])
              for i in range(len(cuts) - 1)]
    for start, data in reversed(pieces):
        stream.sink(start, data)
    restored = stream.finish()
    assert _trees_equal(state, restored)


def test_streaming_restore_respects_shardings(tmp_path):
    """Streamed leaves land with the requested sharding (the H2D overlap
    must not lose the placement contract)."""
    state = {"w": jnp.arange(16 * 16, dtype=jnp.float32).reshape(16, 16)}
    d = save_checkpoint(str(tmp_path), 2, state)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    shardings = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))}

    from repro.checkpoint.manager import _StreamingRestore, _MANIFEST, _DATA
    manifest = json.load(open(os.path.join(d, _MANIFEST)))
    blob = open(os.path.join(d, _DATA), "rb").read()
    stream = _StreamingRestore(manifest, state, shardings)
    stream.sink(0, blob)
    restored = stream.finish()
    assert _trees_equal(state, restored)
    assert restored["w"].sharding.spec == jax.sharding.PartitionSpec(
        "data", "model")


def test_elastic_restore_resharding(tmp_path):
    """Restore with explicit target shardings (single-device 'mesh' here;
    the dry-run exercises the 512-device version of the same call)."""
    state = {"w": jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64)}
    save_checkpoint(str(tmp_path), 11, state)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    shardings = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))}
    restored, _ = restore_checkpoint(str(tmp_path), state,
                                     shardings=shardings)
    assert _trees_equal(state, restored)
    assert restored["w"].sharding.spec == jax.sharding.PartitionSpec(
        "data", "model")
