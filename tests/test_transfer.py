"""Integration tests: real asyncio MDTP client over localhost HTTP mirrors."""

import hashlib

import numpy as np
import pytest

from repro.core.chunking import ChunkParams
from repro.transfer import MDTPClient, RangeServer, Replica, Throttle, fetch_blob

MB = 1024 * 1024


def _mirrors(blob, throttles):
    servers = []
    for th in throttles:
        s = RangeServer(throttle=th).start()
        s.add_blob("/data", blob)
        servers.append(s)
    return servers


@pytest.fixture
def blob():
    rng = np.random.default_rng(0)
    return rng.integers(0, 256, size=8 * MB, dtype=np.uint8).tobytes()


def test_roundtrip_integrity(blob):
    servers = _mirrors(blob, [Throttle(bytes_per_s=30 * MB),
                              Throttle(bytes_per_s=60 * MB),
                              Throttle(bytes_per_s=120 * MB)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        params = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
        # the proportionality claim is wall-clock-sensitive: on a loaded
        # CI box even the 4x spread can transiently invert, so allow one
        # retry for that assertion alone (integrity stays strict per run;
        # the steady-state claim is covered deterministically by the
        # simulator tests)
        for attempt in range(2):
            data, report = fetch_blob(replicas, len(blob), params=params)
            assert hashlib.sha256(data).hexdigest() == \
                hashlib.sha256(blob).hexdigest()
            # every mirror contributed, and the 4x-faster mirror beat the
            # slowest.  (Strict ordering of the top two is NOT asserted:
            # the 60 vs 120 MB/s estimates invert too easily.)
            contributions = [report.bytes_per_replica[r.name]
                             for r in replicas]
            assert all(c > 0 for c in contributions)
            assert report.failed_replicas == []
            # per-replica RTT was measured (connect + header turnaround):
            # every contributing mirror has a positive, sane sample
            for r in replicas:
                assert 0.0 < report.observed_rtts[r.name] < 5.0
            if contributions[2] > contributions[0]:
                break
        else:
            assert contributions[2] > contributions[0]
    finally:
        for s in servers:
            s.stop()


def test_retune_uses_measured_rtts():
    """retune feeds the fused tuner the MEASURED per-replica RTTs from the
    last transfer (falling back to the default only for replicas that
    never produced a sample), not a hardcoded constant."""
    from repro.core.autotune import autotune_chunk_params
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    client = MDTPClient(replicas)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={},
        requests_per_replica={}, failed_replicas=[], refetched_ranges=0,
        observed_throughputs={"h0:1": 50.0 * MB, "h1:2": 10.0 * MB},
        observed_rtts={"h0:1": 0.25, "h1:2": 0.0})  # h1 never sampled
    res = client.retune(2 * GB)
    expect = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], rtt=[0.25, MDTPClient.DEFAULT_RTT],
        file_size=2 * GB)
    assert res.predicted_times == expect.predicted_times
    assert res.params == expect.params
    # a quarter-second RTT penalizes small chunks: the winner must differ
    # from the low-latency tune unless both argmins coincide by chance —
    # at minimum the predicted times must reflect the measured latency
    low_lat = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], rtt=0.001, file_size=2 * GB)
    assert res.predicted_time > low_lat.predicted_time


def test_retune_all_dead_replica_telemetry():
    """A transfer whose every replica failed (or never produced a sample)
    must make retune raise — and leave the adopted params untouched — not
    feed a zero-bandwidth fleet into the simulated sweep, where any grid
    point would 'win' with an infinite predicted time."""
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    before = ChunkParams(initial_chunk=2 * MB, large_chunk=20 * MB)
    client = MDTPClient(replicas, params=before)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={},
        requests_per_replica={}, failed_replicas=["h0:1", "h1:2"],
        refetched_ranges=0,
        observed_throughputs={"h0:1": 0.0, "h1:2": 0.0},
        observed_rtts={"h0:1": 0.02, "h1:2": 0.02})
    with pytest.raises(RuntimeError, match="no throughput"):
        client.retune(2 * GB)
    assert client._params_arg == before
    # a single live replica is enough again
    client.last_report.observed_throughputs["h1:2"] = 40.0 * MB
    res = client.retune(2 * GB)
    assert client._params_arg == res.params


def test_adaptive_chunks_scale_with_throughput(blob):
    """Slow mirror must get smaller requests, not fewer-by-starvation —
    the paper's load-proportionality claim on the real runtime."""
    servers = _mirrors(blob, [Throttle(bytes_per_s=15 * MB),
                              Throttle(bytes_per_s=120 * MB)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        params = ChunkParams(initial_chunk=128 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params)
        assert bytes(data) == blob
        slow, fast = (report.bytes_per_replica[r.name] for r in replicas)
        assert fast > 2 * slow
        # request counts stay comparable (sizes adapt instead) — Fig. 5c
        rs, rf = (report.requests_per_replica[r.name] for r in replicas)
        assert rs >= max(1, rf // 4)
    finally:
        for s in servers:
            s.stop()


def test_mirror_death_mid_transfer(blob):
    """Kill a mirror while it still owes bytes: the range pool reassigns its
    outstanding range and the transfer completes exactly."""
    victim = RangeServer(throttle=Throttle(bytes_per_s=4 * MB)).start()
    victim.add_blob("/data", blob)
    healthy = RangeServer(throttle=Throttle(bytes_per_s=60 * MB)).start()
    healthy.add_blob("/data", blob)
    try:
        replicas = [Replica("127.0.0.1", victim.port, "/data"),
                    Replica("127.0.0.1", healthy.port, "/data")]
        import threading
        killer = threading.Timer(0.15, victim.stop)
        killer.start()
        params = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params,
                                  max_failures=2)
        assert bytes(data) == blob
    finally:
        healthy.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_sink_exception_propagates_promptly(blob):
    """A raising sink (e.g. disk full mid-stream) must propagate out of
    fetch instead of stranding sibling workers on the in-flight range
    accounting."""
    import asyncio

    from repro.transfer.client import MDTPClient

    servers = _mirrors(blob, [Throttle(bytes_per_s=40 * MB),
                              Throttle(bytes_per_s=40 * MB)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        client = MDTPClient(
            replicas, params=ChunkParams(256 * 1024, MB))

        def bad_sink(start, data):
            raise ValueError("disk full")

        with pytest.raises(ValueError):
            asyncio.run(client.fetch(len(blob), sink=bad_sink))
    finally:
        for s in servers:
            s.stop()


def test_all_mirrors_dead_raises(blob):
    s = RangeServer().start()
    s.add_blob("/data", blob[:MB])
    port = s.port
    s.stop()
    with pytest.raises((IOError, OSError)):
        fetch_blob([Replica("127.0.0.1", port, "/data")], MB)


def test_blob_size_head(blob):
    s = RangeServer().start()
    s.add_blob("/data", blob)
    try:
        data, _ = fetch_blob([Replica("127.0.0.1", s.port, "/data")])
        assert bytes(data) == blob
    finally:
        s.stop()
