"""Integration tests: real asyncio MDTP client over localhost HTTP mirrors."""

import hashlib

import numpy as np
import pytest

from repro.core.chunking import ChunkParams
from repro.transfer import MDTPClient, RangeServer, Replica, Throttle, fetch_blob

MB = 1024 * 1024


def _mirrors(blob, throttles):
    servers = []
    for th in throttles:
        s = RangeServer(throttle=th).start()
        s.add_blob("/data", blob)
        servers.append(s)
    return servers


@pytest.fixture
def blob():
    rng = np.random.default_rng(0)
    return rng.integers(0, 256, size=8 * MB, dtype=np.uint8).tobytes()


def test_roundtrip_integrity(blob):
    # deterministically paced mirrors: each piece pays its wire time as an
    # unconditional token-bucket sleep, so the 30/60/120 rate ratios hold
    # regardless of host load and the proportionality assertion needs no
    # retry guard (wall-clock compensation pacing could be erased by a
    # loaded box, transiently inverting the mirrors' relative rates)
    servers = _mirrors(blob, [
        Throttle(bytes_per_s=30 * MB, deterministic=True),
        Throttle(bytes_per_s=60 * MB, deterministic=True),
        Throttle(bytes_per_s=120 * MB, deterministic=True)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        params = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params)
        assert hashlib.sha256(data).hexdigest() == \
            hashlib.sha256(blob).hexdigest()
        # every mirror contributed, and the 4x-faster mirror beat the
        # slowest.  (Strict ordering of the top two is NOT asserted:
        # the 60 vs 120 MB/s estimates sit too close.)
        contributions = [report.bytes_per_replica[r.name]
                         for r in replicas]
        assert all(c > 0 for c in contributions)
        assert report.failed_replicas == []
        # per-replica RTT was measured (connect + header turnaround):
        # every contributing mirror has a positive, sane sample
        for r in replicas:
            assert 0.0 < report.observed_rtts[r.name] < 5.0
        assert contributions[2] > contributions[0]
    finally:
        for s in servers:
            s.stop()


def test_retune_uses_measured_rtts():
    """retune feeds the fused tuner the MEASURED per-replica RTTs from the
    last transfer (falling back to the default only for replicas that
    never produced a sample), not a hardcoded constant — and the client's
    own pipeline depth, so the sweep models the runtime's actual request
    overlap."""
    from repro.core.autotune import autotune_chunk_params
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    client = MDTPClient(replicas, pipeline_depth=3)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={},
        requests_per_replica={}, failed_replicas=[], refetched_ranges=0,
        observed_throughputs={"h0:1": 50.0 * MB, "h1:2": 10.0 * MB},
        observed_rtts={"h0:1": 0.25, "h1:2": 0.0})  # h1 never sampled
    res = client.retune(2 * GB)
    expect = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], rtt=[0.25, MDTPClient.DEFAULT_RTT],
        file_size=2 * GB, pipeline_depth=3)
    assert res.predicted_times == expect.predicted_times
    assert res.params == expect.params
    # a quarter-second RTT penalizes small chunks: the winner must differ
    # from the low-latency tune unless both argmins coincide by chance —
    # at minimum the predicted times must reflect the measured latency
    low_lat = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], rtt=0.001, file_size=2 * GB,
        pipeline_depth=3)
    assert res.predicted_time > low_lat.predicted_time


def test_wire_elapsed_strips_request_rtt():
    """Regression for the observation-point bias correction: a serial
    (idle-pipe) chunk observation spans rtt + body time, and
    ``wire_elapsed`` recovers the on-wire body time exactly; impossible
    corrections pass the elapsed through unchanged."""
    from repro.transfer.client import wire_elapsed

    wire, rtt, chunk = 70.0 * MB, 0.5, 40.0 * MB
    elapsed = rtt + chunk / wire
    corrected = wire_elapsed(int(chunk), elapsed, rtt)
    assert corrected == pytest.approx(chunk / wire, rel=1e-9)
    assert int(chunk) / corrected == pytest.approx(wire, rel=1e-9)
    # no RTT sample -> passthrough; implied non-positive wire time ->
    # passthrough; degenerate inputs -> passthrough
    assert wire_elapsed(int(chunk), elapsed, 0.0) == elapsed
    assert wire_elapsed(int(chunk), 0.3, 0.5) == 0.3
    assert wire_elapsed(0, 1.0, 0.5) == 1.0
    assert wire_elapsed(int(chunk), 0.0, 0.5) == 0.0


def test_retune_passes_wire_rates_through():
    """``observed_throughputs`` are already wire rates (the RTT bias is
    stripped per observation via ``wire_elapsed``), so ``retune`` must
    feed them to the fused sweep UNCHANGED — re-applying
    ``rtt_corrected_bandwidth`` on top would overstate every high-RTT
    replica's capacity."""
    from repro.core.autotune import autotune_chunk_params
    from repro.core.throughput import rtt_corrected_bandwidth
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    wire = {"h0:1": 70.0 * MB, "h1:2": 12.0 * MB}
    rtts = {"h0:1": 0.5, "h1:2": 0.03}
    client = MDTPClient(replicas, pipeline_depth=1)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0,
        bytes_per_replica={n: 320 * MB for n in wire},
        requests_per_replica={n: 8 for n in wire},
        failed_replicas=[], refetched_ranges=0,
        observed_throughputs=dict(wire), observed_rtts=rtts)
    res = client.retune(2 * GB)
    expect = autotune_chunk_params(
        [wire["h0:1"], wire["h1:2"]], rtt=[rtts["h0:1"], rtts["h1:2"]],
        file_size=2 * GB, pipeline_depth=1)
    assert res.predicted_times == expect.predicted_times
    assert res.params == expect.params
    # and NOT a double-corrected (inflated) fleet
    inflated = [rtt_corrected_bandwidth(wire[n], rtts[n], 40.0 * MB)
                for n in ("h0:1", "h1:2")]
    assert inflated[0] > wire["h0:1"]               # the hazard is real
    double = autotune_chunk_params(
        inflated, rtt=[rtts["h0:1"], rtts["h1:2"]], file_size=2 * GB,
        pipeline_depth=1)
    assert res.predicted_times != double.predicted_times


def test_retune_all_dead_replica_telemetry():
    """A transfer whose every replica failed (or never produced a sample)
    must make retune raise — and leave the adopted params untouched — not
    feed a zero-bandwidth fleet into the simulated sweep, where any grid
    point would 'win' with an infinite predicted time."""
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    before = ChunkParams(initial_chunk=2 * MB, large_chunk=20 * MB)
    client = MDTPClient(replicas, params=before)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={},
        requests_per_replica={}, failed_replicas=["h0:1", "h1:2"],
        refetched_ranges=0,
        observed_throughputs={"h0:1": 0.0, "h1:2": 0.0},
        observed_rtts={"h0:1": 0.02, "h1:2": 0.02})
    with pytest.raises(RuntimeError, match="no throughput"):
        client.retune(2 * GB)
    assert client._params_arg == before
    # a single live replica is enough again
    client.last_report.observed_throughputs["h1:2"] = 40.0 * MB
    res = client.retune(2 * GB)
    assert client._params_arg == res.params


def test_adaptive_chunks_scale_with_throughput(blob):
    """Slow mirror must get smaller requests, not fewer-by-starvation —
    the paper's load-proportionality claim on the real runtime."""
    servers = _mirrors(blob, [
        Throttle(bytes_per_s=15 * MB, deterministic=True),
        Throttle(bytes_per_s=120 * MB, deterministic=True)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        params = ChunkParams(initial_chunk=128 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params)
        assert bytes(data) == blob
        slow, fast = (report.bytes_per_replica[r.name] for r in replicas)
        assert fast > 2 * slow
        # request counts stay comparable (sizes adapt instead) — Fig. 5c
        rs, rf = (report.requests_per_replica[r.name] for r in replicas)
        assert rs >= max(1, rf // 4)
    finally:
        for s in servers:
            s.stop()


def test_mirror_death_mid_transfer(blob):
    """Kill a mirror while it still owes bytes: the range pool reassigns its
    outstanding range and the transfer completes exactly."""
    victim = RangeServer(throttle=Throttle(bytes_per_s=4 * MB)).start()
    victim.add_blob("/data", blob)
    healthy = RangeServer(throttle=Throttle(bytes_per_s=60 * MB)).start()
    healthy.add_blob("/data", blob)
    try:
        replicas = [Replica("127.0.0.1", victim.port, "/data"),
                    Replica("127.0.0.1", healthy.port, "/data")]
        import threading
        killer = threading.Timer(0.15, victim.stop)
        killer.start()
        params = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params,
                                  max_failures=2)
        assert bytes(data) == blob
    finally:
        healthy.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_sink_exception_propagates_promptly(blob):
    """A raising sink (e.g. disk full mid-stream) must propagate out of
    fetch instead of stranding sibling workers on the in-flight range
    accounting."""
    import asyncio

    from repro.transfer.client import MDTPClient

    servers = _mirrors(blob, [Throttle(bytes_per_s=40 * MB),
                              Throttle(bytes_per_s=40 * MB)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        client = MDTPClient(
            replicas, params=ChunkParams(256 * 1024, MB))

        def bad_sink(start, data):
            raise ValueError("disk full")

        with pytest.raises(ValueError):
            asyncio.run(client.fetch(len(blob), sink=bad_sink))
    finally:
        for s in servers:
            s.stop()


def test_all_mirrors_dead_raises(blob):
    s = RangeServer().start()
    s.add_blob("/data", blob[:MB])
    port = s.port
    s.stop()
    with pytest.raises((IOError, OSError)):
        fetch_blob([Replica("127.0.0.1", port, "/data")], MB)


def test_blob_size_head(blob):
    s = RangeServer().start()
    s.add_blob("/data", blob)
    try:
        data, _ = fetch_blob([Replica("127.0.0.1", s.port, "/data")])
        assert bytes(data) == blob
    finally:
        s.stop()


def test_pipelined_connection_death_repools_every_owed_range(blob):
    """Kill a mirror while its connection holds a deep pipeline of
    in-flight ranges: every owed range must re-enter the pool exactly
    once, the survivors must finish the transfer, and the assembled bytes
    must hash identical — delivered-byte conservation (== size) is the
    exactly-once witness: a dropped range fails the fetch with IOError, a
    double-pooled one overshoots ``done_bytes``."""
    import threading

    # slow deterministic victim so several pipelined requests are still
    # in flight when it dies; small chunks keep the pipeline populated
    victim = RangeServer(
        throttle=Throttle(bytes_per_s=4 * MB, deterministic=True)).start()
    victim.add_blob("/data", blob)
    healthy = RangeServer(
        throttle=Throttle(bytes_per_s=30 * MB, deterministic=True)).start()
    healthy.add_blob("/data", blob)
    try:
        replicas = [Replica("127.0.0.1", victim.port, "/data"),
                    Replica("127.0.0.1", healthy.port, "/data")]

        def kill():
            # sever the live stream (pipelined requests mid-flight) AND
            # the listener, so reconnect attempts fail too
            victim.kill_connections()
            victim.stop()

        killer = threading.Timer(0.1, kill)
        killer.start()
        params = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params,
                                  max_failures=2, pipeline_depth=4)
        assert hashlib.sha256(bytes(data)).hexdigest() == \
            hashlib.sha256(blob).hexdigest()
        # conservation: each byte delivered exactly once, no range lost
        # or duplicated across the re-pool
        assert sum(report.bytes_per_replica.values()) == len(blob)
        assert report.refetched_ranges >= 1
        assert report.failed_replicas == [replicas[0].name]
    finally:
        healthy.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_serial_depth_one_still_works(blob):
    """pipeline_depth=1 degrades to the serial request-response plane."""
    s = RangeServer().start()
    s.add_blob("/data", blob)
    try:
        data, report = fetch_blob(
            [Replica("127.0.0.1", s.port, "/data")], len(blob),
            params=ChunkParams(initial_chunk=256 * 1024, large_chunk=MB),
            pipeline_depth=1)
        assert bytes(data) == blob
    finally:
        s.stop()


def test_copy_mode_fallback_matches(blob):
    """``zero_copy=False`` (the legacy bytes-assembly path, kept as the
    benchmark baseline) still produces identical bytes."""
    s = RangeServer().start()
    s.add_blob("/data", blob)
    try:
        data, _ = fetch_blob(
            [Replica("127.0.0.1", s.port, "/data")], len(blob),
            params=ChunkParams(initial_chunk=256 * 1024, large_chunk=MB),
            zero_copy=False)
        assert bytes(data) == blob
    finally:
        s.stop()


def test_callable_sink_receives_transient_memoryviews(blob):
    """Callable sinks get memoryviews (zero materialized ``bytes`` on the
    receive path) and must copy before returning — the client recycles
    nothing the sink can keep."""
    import asyncio

    from repro.transfer.client import MDTPClient

    s = RangeServer().start()
    s.add_blob("/data", blob)
    try:
        got = bytearray(len(blob))
        kinds = set()

        def sink(start, view):
            kinds.add(type(view))
            got[start:start + len(view)] = view

        client = MDTPClient(
            [Replica("127.0.0.1", s.port, "/data")],
            params=ChunkParams(256 * 1024, MB))
        asyncio.run(client.fetch(len(blob), sink=sink))
        assert bytes(got) == blob
        assert kinds == {memoryview}
    finally:
        s.stop()


def test_writable_commit_sink_is_zero_copy_destination(blob):
    """The ``writable``/``commit`` sink protocol: the client reads socket
    bytes straight into the buffer the sink exposes and commits exactly
    the landed spans (each byte exactly once)."""
    import asyncio

    from repro.transfer.client import MDTPClient

    s = RangeServer().start()
    s.add_blob("/data", blob)
    try:
        class ZeroCopySink:
            def __init__(self, size):
                self.buf = bytearray(size)
                self.committed = 0
                self.views = []

            def writable(self, start, length):
                view = memoryview(self.buf)[start:start + length]
                self.views.append((start, length))
                return view

            def commit(self, start, nbytes):
                self.committed += nbytes

        zc = ZeroCopySink(len(blob))
        client = MDTPClient(
            [Replica("127.0.0.1", s.port, "/data")],
            params=ChunkParams(256 * 1024, MB))
        asyncio.run(client.fetch(len(blob), sink=zc))
        assert bytes(zc.buf) == blob
        assert zc.committed == len(blob)     # exactly-once accounting
        assert zc.views                       # the zero-copy path was used
    finally:
        s.stop()


def test_half_sink_protocol_rejected(blob):
    """A sink with ``writable`` but no ``commit`` (or vice versa) is a
    contract bug — fail loudly instead of silently copying."""
    import asyncio

    from repro.transfer.client import MDTPClient

    class Half:
        def writable(self, start, length):
            return memoryview(bytearray(length))

    client = MDTPClient([Replica("127.0.0.1", 1, "/data")])
    with pytest.raises(TypeError, match="writable"):
        asyncio.run(client.fetch(MB, sink=Half()))
