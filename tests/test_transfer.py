"""Integration tests: real asyncio MDTP client over localhost HTTP mirrors."""

import hashlib

import numpy as np
import pytest

from repro.core.chunking import ChunkParams
from repro.transfer import MDTPClient, RangeServer, Replica, Throttle, fetch_blob

MB = 1024 * 1024


def _mirrors(blob, throttles):
    servers = []
    for th in throttles:
        s = RangeServer(throttle=th).start()
        s.add_blob("/data", blob)
        servers.append(s)
    return servers


@pytest.fixture
def blob():
    rng = np.random.default_rng(0)
    return rng.integers(0, 256, size=8 * MB, dtype=np.uint8).tobytes()


def test_roundtrip_integrity(blob):
    # deterministically paced mirrors: each piece pays its wire time as an
    # unconditional token-bucket sleep, so the 30/60/120 rate ratios hold
    # regardless of host load and the proportionality assertion needs no
    # retry guard (wall-clock compensation pacing could be erased by a
    # loaded box, transiently inverting the mirrors' relative rates)
    servers = _mirrors(blob, [
        Throttle(bytes_per_s=30 * MB, deterministic=True),
        Throttle(bytes_per_s=60 * MB, deterministic=True),
        Throttle(bytes_per_s=120 * MB, deterministic=True)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        params = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params)
        assert hashlib.sha256(data).hexdigest() == \
            hashlib.sha256(blob).hexdigest()
        # every mirror contributed, and the 4x-faster mirror beat the
        # slowest.  (Strict ordering of the top two is NOT asserted:
        # the 60 vs 120 MB/s estimates sit too close.)
        contributions = [report.bytes_per_replica[r.name]
                         for r in replicas]
        assert all(c > 0 for c in contributions)
        assert report.failed_replicas == []
        # per-replica RTT was measured (connect + header turnaround):
        # every contributing mirror has a positive, sane sample
        for r in replicas:
            assert 0.0 < report.observed_rtts[r.name] < 5.0
        assert contributions[2] > contributions[0]
    finally:
        for s in servers:
            s.stop()


def test_retune_uses_measured_rtts():
    """retune feeds the fused tuner the MEASURED per-replica RTTs from the
    last transfer (falling back to the default only for replicas that
    never produced a sample), not a hardcoded constant."""
    from repro.core.autotune import autotune_chunk_params
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    client = MDTPClient(replicas)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={},
        requests_per_replica={}, failed_replicas=[], refetched_ranges=0,
        observed_throughputs={"h0:1": 50.0 * MB, "h1:2": 10.0 * MB},
        observed_rtts={"h0:1": 0.25, "h1:2": 0.0})  # h1 never sampled
    res = client.retune(2 * GB)
    expect = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], rtt=[0.25, MDTPClient.DEFAULT_RTT],
        file_size=2 * GB)
    assert res.predicted_times == expect.predicted_times
    assert res.params == expect.params
    # a quarter-second RTT penalizes small chunks: the winner must differ
    # from the low-latency tune unless both argmins coincide by chance —
    # at minimum the predicted times must reflect the measured latency
    low_lat = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], rtt=0.001, file_size=2 * GB)
    assert res.predicted_time > low_lat.predicted_time


def test_retune_corrects_estimator_rtt_bias():
    """Regression: the per-request estimator's biased readings are
    corrected back to the wire rate (via the measured RTT and mean served
    chunk) BEFORE they reach the fused tuner.  Uncorrected, the bias
    systematically under-weights high-RTT replicas in re-tuning — a
    40 MB-chunk mirror at 70 MB/s behind 0.5 s RTT reads as ~37 MB/s."""
    from repro.core.autotune import autotune_chunk_params
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    wire = {"h0:1": 70.0 * MB, "h1:2": 12.0 * MB}
    rtts = {"h0:1": 0.5, "h1:2": 0.03}
    chunk = {"h0:1": 40.0 * MB, "h1:2": 2.0 * MB}
    # what the estimator actually observes: s / (rtt + s / bw)
    biased = {n: chunk[n] / (rtts[n] + chunk[n] / wire[n]) for n in wire}
    assert all(biased[n] < wire[n] for n in wire)
    client = MDTPClient(replicas)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0,
        bytes_per_replica={n: int(chunk[n] * 8) for n in wire},
        requests_per_replica={n: 8 for n in wire},
        failed_replicas=[], refetched_ranges=0,
        observed_throughputs=biased, observed_rtts=rtts)
    res = client.retune(2 * GB)
    # the tuner must have been fed the RECOVERED wire rates
    expect = autotune_chunk_params(
        [wire["h0:1"], wire["h1:2"]], rtt=[rtts["h0:1"], rtts["h1:2"]],
        file_size=2 * GB)
    assert res.predicted_times == expect.predicted_times
    assert res.params == expect.params
    # and NOT the biased readings
    biased_res = autotune_chunk_params(
        [biased["h0:1"], biased["h1:2"]],
        rtt=[rtts["h0:1"], rtts["h1:2"]], file_size=2 * GB)
    assert res.predicted_times != biased_res.predicted_times


def test_fetch_telemetry_bandwidth_is_rtt_corrected():
    """Regression for the in-fetch Telemetry snapshots: the bandwidth
    vector handed to ``tuner.update`` carries RTT-bias-corrected
    estimates (full-fleet positional contract preserved: dead slot 0.0,
    un-correctable readings passed through)."""
    from repro.transfer.client import Replica, _corrected_bandwidths

    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b"),
                Replica("h2", 3, "/b")]
    wire, rtt, chunk = 70.0 * MB, 0.5, 40.0 * MB
    biased = chunk / (rtt + chunk / wire)
    bw = _corrected_bandwidths(
        replicas,
        est_values=[biased, 50.0 * MB, 5.0 * MB],
        rtt_min=[rtt, 0.0, 0.2],
        failed=["h2:3"],
        bytes_per={"h0:1": int(chunk * 4), "h1:2": 10 * MB, "h2:3": 0},
        reqs_per={"h0:1": 4, "h1:2": 2, "h2:3": 0})
    assert bw[0] == pytest.approx(wire, rel=1e-6)   # bias inverted
    assert bw[1] == 50.0 * MB                       # no RTT sample: as-is
    assert bw[2] == 0.0                             # dead slot preserved


def test_retune_all_dead_replica_telemetry():
    """A transfer whose every replica failed (or never produced a sample)
    must make retune raise — and leave the adopted params untouched — not
    feed a zero-bandwidth fleet into the simulated sweep, where any grid
    point would 'win' with an infinite predicted time."""
    from repro.transfer.client import MDTPClient, Replica, TransferReport

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    before = ChunkParams(initial_chunk=2 * MB, large_chunk=20 * MB)
    client = MDTPClient(replicas, params=before)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={},
        requests_per_replica={}, failed_replicas=["h0:1", "h1:2"],
        refetched_ranges=0,
        observed_throughputs={"h0:1": 0.0, "h1:2": 0.0},
        observed_rtts={"h0:1": 0.02, "h1:2": 0.02})
    with pytest.raises(RuntimeError, match="no throughput"):
        client.retune(2 * GB)
    assert client._params_arg == before
    # a single live replica is enough again
    client.last_report.observed_throughputs["h1:2"] = 40.0 * MB
    res = client.retune(2 * GB)
    assert client._params_arg == res.params


def test_adaptive_chunks_scale_with_throughput(blob):
    """Slow mirror must get smaller requests, not fewer-by-starvation —
    the paper's load-proportionality claim on the real runtime."""
    servers = _mirrors(blob, [
        Throttle(bytes_per_s=15 * MB, deterministic=True),
        Throttle(bytes_per_s=120 * MB, deterministic=True)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        params = ChunkParams(initial_chunk=128 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params)
        assert bytes(data) == blob
        slow, fast = (report.bytes_per_replica[r.name] for r in replicas)
        assert fast > 2 * slow
        # request counts stay comparable (sizes adapt instead) — Fig. 5c
        rs, rf = (report.requests_per_replica[r.name] for r in replicas)
        assert rs >= max(1, rf // 4)
    finally:
        for s in servers:
            s.stop()


def test_mirror_death_mid_transfer(blob):
    """Kill a mirror while it still owes bytes: the range pool reassigns its
    outstanding range and the transfer completes exactly."""
    victim = RangeServer(throttle=Throttle(bytes_per_s=4 * MB)).start()
    victim.add_blob("/data", blob)
    healthy = RangeServer(throttle=Throttle(bytes_per_s=60 * MB)).start()
    healthy.add_blob("/data", blob)
    try:
        replicas = [Replica("127.0.0.1", victim.port, "/data"),
                    Replica("127.0.0.1", healthy.port, "/data")]
        import threading
        killer = threading.Timer(0.15, victim.stop)
        killer.start()
        params = ChunkParams(initial_chunk=256 * 1024, large_chunk=MB)
        data, report = fetch_blob(replicas, len(blob), params=params,
                                  max_failures=2)
        assert bytes(data) == blob
    finally:
        healthy.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_sink_exception_propagates_promptly(blob):
    """A raising sink (e.g. disk full mid-stream) must propagate out of
    fetch instead of stranding sibling workers on the in-flight range
    accounting."""
    import asyncio

    from repro.transfer.client import MDTPClient

    servers = _mirrors(blob, [Throttle(bytes_per_s=40 * MB),
                              Throttle(bytes_per_s=40 * MB)])
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        client = MDTPClient(
            replicas, params=ChunkParams(256 * 1024, MB))

        def bad_sink(start, data):
            raise ValueError("disk full")

        with pytest.raises(ValueError):
            asyncio.run(client.fetch(len(blob), sink=bad_sink))
    finally:
        for s in servers:
            s.stop()


def test_all_mirrors_dead_raises(blob):
    s = RangeServer().start()
    s.add_blob("/data", blob[:MB])
    port = s.port
    s.stop()
    with pytest.raises((IOError, OSError)):
        fetch_blob([Replica("127.0.0.1", port, "/data")], MB)


def test_blob_size_head(blob):
    s = RangeServer().start()
    s.add_blob("/data", blob)
    try:
        data, _ = fetch_blob([Replica("127.0.0.1", s.port, "/data")])
        assert bytes(data) == blob
    finally:
        s.stop()
