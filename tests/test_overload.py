"""Overload-robustness matrix: replica probation, admission control,
hedged endgame, and the flash-crowd scenario/simulator mirrors.

The probation cases drive ``FleetModel`` directly (no sockets — pure
state-machine checks on strikes, trips, and slow-start readmission).
The end-to-end cases run real loopback fleets and assert the full-file
checksum plus the report witnesses: robustness must be invisible in the
delivered bytes and visible only in the accounting.
"""

import asyncio
import hashlib
import random

import numpy as np
import pytest

from repro.core import MDTPPolicy, simulate
from repro.core.chunking import ChunkParams
from repro.core.scenarios import (
    flash_crowd_traces,
    paper_baseline,
    with_gray_degradation,
)
from repro.core.simulator import ServerSpec
from repro.transfer import (
    FaultPolicy,
    FleetModel,
    MDTPClient,
    RangeServer,
    Replica,
    Throttle,
    TransferIncompleteError,
    TransferJob,
    TransferManager,
)

MB = 1024 * 1024


def _sha(b) -> str:
    return hashlib.sha256(bytes(b)).hexdigest()


def _blob(size, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()


def _mirror(blob, throttle=None, faults=None):
    s = RangeServer(throttle=throttle, faults=faults).start()
    s.add_blob("/data", blob)
    return s


def _feed(fm, name, rate, n=1, tid="t"):
    """``n`` completed chunks served at ``rate`` bytes/s (1-second
    chunks, pipelined reading so no RTT correction applies)."""
    for _ in range(n):
        fm.observe_chunk(tid, name, int(rate), 1.0, rtt_included=False)


# --------------------------------------------------------------------------
# Replica probation (FleetModel unit)
# --------------------------------------------------------------------------


def test_slow_strikes_trip_probation():
    """``probation_strikes`` consecutive chunks far below the best
    trusted peer put a mirror on probation — the fast path for a gray
    mirror whose capacity EWMA is still coasting on its healthy past."""
    fm = FleetModel()
    _feed(fm, "a", 50 * MB, n=6)
    _feed(fm, "b", 45 * MB, n=4)          # trusted history, >= 4 chunks
    assert fm.probations == 0
    _feed(fm, "b", 1 * MB, n=fm.probation_strikes)
    assert fm.probations == 1
    assert fm.snapshot()["b"]["probation"] is True


def test_slow_strike_streak_resets_on_healthy_chunk():
    fm = FleetModel()
    _feed(fm, "a", 50 * MB, n=6)
    _feed(fm, "b", 45 * MB, n=4)
    _feed(fm, "b", 1 * MB, n=fm.probation_strikes - 1)
    _feed(fm, "b", 45 * MB)               # healthy chunk clears the streak
    _feed(fm, "b", 1 * MB, n=fm.probation_strikes - 1)
    assert fm.probations == 0


def test_probation_readmission_is_slow_start():
    """A probated mirror re-enters only after a clean streak of
    fast-probe chunks, at ``readmit_init`` of its fair share, and earns
    the rest back multiplicatively."""
    fm = FleetModel()
    _feed(fm, "a", 50 * MB, n=6)
    _feed(fm, "b", 45 * MB, n=4)
    _feed(fm, "b", 1 * MB, n=fm.probation_strikes)
    assert fm.snapshot()["b"]["probation"] is True
    _feed(fm, "b", 45 * MB, n=fm.probation_clean_streak)
    snap = fm.snapshot()["b"]
    assert snap["probation"] is False
    assert snap["readmit"] == pytest.approx(fm.readmit_init)
    _feed(fm, "b", 45 * MB)               # each clean chunk doubles it
    assert fm.snapshot()["b"]["readmit"] == pytest.approx(
        min(1.0, fm.readmit_init * 2.0))


def test_probation_slow_probes_do_not_readmit():
    """Clean is necessary but not sufficient: a mirror whose probe
    chunks still crawl stays parked however long the streak."""
    fm = FleetModel()
    _feed(fm, "a", 50 * MB, n=6)
    _feed(fm, "b", 45 * MB, n=4)
    _feed(fm, "b", 1 * MB, n=fm.probation_strikes)
    _feed(fm, "b", 1 * MB, n=3 * fm.probation_clean_streak)
    assert fm.snapshot()["b"]["probation"] is True


def test_single_replica_fleet_never_trips():
    """With nothing faster to shift toward, slowness is not a fault."""
    fm = FleetModel()
    _feed(fm, "solo", 1 * MB, n=20)
    assert fm.probations == 0


def test_corruption_decay_trips_probation():
    fm = FleetModel()
    for _ in range(5):                    # health 1.0 -> ~0.17 < 0.3
        fm.observe_corruption("bad")
    assert fm.snapshot()["bad"]["probation"] is True


def test_retry_storm_trips_probation_without_chunks():
    """A blackholed mirror that never completes a chunk still lands on
    probation once enough reconnects accumulate."""
    fm = FleetModel()
    for _ in range(fm.probation_retry_limit):
        fm.observe_retry("hole")
    assert fm.snapshot()["hole"]["probation"] is True


def test_probation_pins_allocation_at_probe_floor():
    fm = FleetModel()
    reps = [Replica("h1", 1, "/x"), Replica("h2", 2, "/x")]
    _feed(fm, reps[0].name, 50 * MB, n=6)
    _feed(fm, reps[1].name, 45 * MB, n=4)
    _feed(fm, reps[1].name, 1 * MB, n=fm.probation_strikes)
    view = fm.allocation_view("t2", reps, [40.0 * MB, 40.0 * MB])
    cap = fm.snapshot()[reps[1].name]["capacity"]
    assert view[1] == pytest.approx(cap * fm.probation_floor)
    assert view[0] > view[1]


def test_probation_disabled_never_trips():
    fm = FleetModel(probation=False)
    _feed(fm, "a", 50 * MB, n=6)
    _feed(fm, "b", 45 * MB, n=4)
    _feed(fm, "b", 1 * MB, n=20)
    assert fm.probations == 0


# --------------------------------------------------------------------------
# Admission control (manager, real sockets)
# --------------------------------------------------------------------------


def test_admission_gate_queues_excess_arrivals():
    blob = _blob(MB)
    servers = [_mirror(blob) for _ in range(2)]
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        mgr = TransferManager(
            replicas, params=ChunkParams(initial_chunk=128 * 1024,
                                         large_chunk=256 * 1024),
            max_active_transfers=1)
        results = mgr.run([TransferJob(size=len(blob)) for _ in range(3)])
        for buf, report in results:
            assert _sha(buf) == _sha(blob)
            assert report.total_bytes == len(blob)
        assert mgr.admission["admitted"] == 3
        assert mgr.admission["queued"] >= 2
        assert mgr.admission["wait_seconds"] > 0.0
    finally:
        for s in servers:
            s.stop()


def test_admission_shed_gives_degraded_service():
    """Arrivals past the shed depth run at trickle pace instead of
    waiting — bounded progress, and the bytes still verify."""
    blob = _blob(512 * 1024)
    servers = [_mirror(blob) for _ in range(2)]
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        mgr = TransferManager(
            replicas, params=ChunkParams(initial_chunk=128 * 1024,
                                         large_chunk=256 * 1024),
            max_active_transfers=1, shed_queue_depth=0,
            shed_trickle_bytes_per_s=64.0 * MB)
        results = mgr.run([TransferJob(size=len(blob)) for _ in range(3)])
        for buf, _ in results:
            assert _sha(buf) == _sha(blob)
        assert mgr.admission["shed"] >= 1
    finally:
        for s in servers:
            s.stop()


def test_srpt_queue_prefers_smallest_residual():
    """With one slot busy, the queued SMALL transfer finishes before the
    queued large one (smallest-remaining-processing-time order)."""
    blob = _blob(2 * MB)
    servers = [_mirror(blob, throttle=Throttle(bytes_per_s=8 * MB,
                                               deterministic=True))
               for _ in range(2)]
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        mgr = TransferManager(
            replicas, params=ChunkParams(initial_chunk=128 * 1024,
                                         large_chunk=256 * 1024),
            max_active_transfers=1)
        small = 256 * 1024
        mgr.run([
            TransferJob(size=len(blob)),                      # holds slot
            TransferJob(size=len(blob), start_delay=0.05),    # queued big
            TransferJob(size=small, start_delay=0.05),        # queued small
        ])
        sizes = [r.total_bytes for r in mgr.reports]
        assert sizes[0] == len(blob)
        assert sizes[1] == small          # small overtook the queued big
    finally:
        for s in servers:
            s.stop()


# --------------------------------------------------------------------------
# Hedged endgame (client, real sockets)
# --------------------------------------------------------------------------


def _gray_fetch(blob, degrade_to=1024, degrade_after=0.05):
    """One hedged transfer where the fast mirror silently starves
    mid-flight; returns (buf, report, servers' served-byte total)."""
    fast = _mirror(blob, throttle=Throttle(bytes_per_s=24 * MB,
                                           deterministic=True))
    slow = _mirror(blob, throttle=Throttle(bytes_per_s=8 * MB,
                                           deterministic=True))
    try:
        replicas = [Replica("127.0.0.1", fast.port, "/data"),
                    Replica("127.0.0.1", slow.port, "/data")]
        client = MDTPClient(
            replicas,
            params=ChunkParams(initial_chunk=128 * 1024,
                               large_chunk=256 * 1024),
            hedge_quantile=0.95, read_timeout=3.0)

        async def go():
            async def grayout():
                await asyncio.sleep(degrade_after)
                fast.set_throttle(Throttle(bytes_per_s=degrade_to,
                                           deterministic=True))
            task = asyncio.ensure_future(grayout())
            try:
                return await client.fetch(len(blob))
            finally:
                task.cancel()

        buf, report = asyncio.run(go())
        return buf, report, fast.served_bytes + slow.served_bytes
    finally:
        fast.stop()
        slow.stop()


def test_hedged_endgame_rescues_gray_straggler():
    """When the fast mirror silently starves, an endgame hedge must win
    the stuck range — and the duplicate bytes must be accounted, not
    silently double-credited."""
    blob = _blob(2 * MB, seed=3)
    buf, report, served = _gray_fetch(blob)
    assert _sha(buf) == _sha(blob)
    assert report.total_bytes == len(blob)     # no hedge over-credit
    assert report.hedges_issued >= 1
    assert report.hedges_won >= 1
    assert report.hedge_wasted_bytes >= 0


def test_hedge_waste_is_conserved_and_bounded():
    """The waste witness counts bytes that really crossed the wire twice
    (it can never exceed the servers' served-byte surplus), and the
    client's fractional budget bounds it at ``hedge_waste_frac * size``
    plus at most one exempted first range."""
    blob = _blob(2 * MB, seed=4)
    buf, report, served = _gray_fetch(blob)
    assert _sha(buf) == _sha(blob)
    assert report.hedge_wasted_bytes <= served - len(blob)
    cap = (MDTPClient([Replica("x", 1, "/")]).hedge_waste_frac * len(blob)
           + 256 * 1024)
    assert report.hedge_wasted_bytes <= cap


def test_hedging_disabled_reports_zero_witnesses():
    blob = _blob(MB, seed=5)
    servers = [_mirror(blob) for _ in range(2)]
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        client = MDTPClient(replicas, hedge_quantile=0.0)
        buf, report = asyncio.run(client.fetch(len(blob)))
        assert _sha(buf) == _sha(blob)
        assert report.hedges_issued == 0
        assert report.hedges_won == 0
        assert report.hedge_wasted_bytes == 0
    finally:
        for s in servers:
            s.stop()


def test_mixed_fault_incomplete_error_accounting():
    """Corruption, resets, and truncation on three distinct mirrors —
    with hedging enabled — must surface as the typed incomplete error
    with honest byte accounting, never a short or over-credited buffer."""
    blob = _blob(MB, seed=6)
    bad = [
        _mirror(blob, faults=FaultPolicy(corrupt_rate=1.0, seed=1)),
        _mirror(blob, faults=FaultPolicy(reset_rate=1.0, seed=2)),
        _mirror(blob, faults=FaultPolicy(truncate_rate=1.0, seed=3)),
    ]
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in bad]
        client = MDTPClient(
            replicas,
            params=ChunkParams(initial_chunk=128 * 1024,
                               large_chunk=256 * 1024),
            hedge_quantile=0.95, max_failures=2)
        with pytest.raises(TransferIncompleteError) as ei:
            asyncio.run(client.fetch(len(blob)))
        err = ei.value
        assert err.expected_bytes == len(blob)
        assert 0 <= err.done_bytes < len(blob)
        for r in replicas:
            assert r.name in err.failed_replicas
    finally:
        for s in bad:
            s.stop()


def test_seeded_backoff_rng_is_honored():
    """Chaos tests can pin reconnect-jitter: an injected seeded RNG is
    used as-is (and two equal seeds draw identical jitter streams)."""
    reps = [Replica("x", 1, "/")]
    c = MDTPClient(reps, rng=random.Random(7))
    twin = random.Random(7)
    assert [c._rng.random() for _ in range(4)] \
        == [twin.random() for _ in range(4)]
    assert MDTPClient(reps)._rng is random


# --------------------------------------------------------------------------
# Scenario + simulator mirrors
# --------------------------------------------------------------------------


def test_flash_crowd_traces_shapes():
    traces = {t.name: t for t in flash_crowd_traces()}
    assert set(traces) == {"burst", "diurnal", "gray-burst"}
    for t in traces.values():
        assert len(t.sizes) == len(t.arrivals)
        assert list(t.arrivals) == sorted(t.arrivals)
        assert all(s > 0 for s in t.sizes)
    grayed = [s for s in traces["gray-burst"].servers
              if s.degrade_factor != 1.0]
    assert len(grayed) == 1
    assert grayed[0].bandwidth == max(
        s.bandwidth for s in traces["gray-burst"].servers)
    assert not any(s.degrade_factor != 1.0 for s in traces["burst"].servers)


def test_with_gray_degradation_targets_one_replica():
    servers = paper_baseline(jitter=0.0)
    grayed = with_gray_degradation(servers, 1.5, 0.2, only=2)
    assert grayed[2].degrade_at == 1.5
    assert grayed[2].degrade_factor == 0.2
    for i, s in enumerate(grayed):
        if i != 2:
            assert s.degrade_factor == 1.0
    assert all(s.degrade_factor == 1.0 for s in servers)  # originals kept


def test_serverspec_gray_degradation_is_silent_and_permanent():
    spec = ServerSpec(name="s", bandwidth=100.0, degrade_at=1.0,
                      degrade_factor=0.25)
    assert spec.bandwidth_at(0.5) == 100.0
    assert spec.bandwidth_at(1.0) == 25.0
    assert spec.bandwidth_at(100.0) == 25.0
    assert 1.0 in spec.rate_boundaries()


def test_simulated_gray_fleet_pays_for_degradation():
    """The python simulator's gray mirror slows the transfer without
    breaking it — same seeds, same fleet, only ``degrade_at`` differs."""
    size = 64 * MB
    servers = paper_baseline(jitter=0.0)
    clean = simulate(MDTPPolicy(), servers, size, seed=0)
    gray = simulate(
        MDTPPolicy(),
        with_gray_degradation(servers, 0.5, 0.05,
                              only=int(np.argmax(
                                  [s.bandwidth for s in servers]))),
        size, seed=0)
    assert sum(clean.bytes_per_server) == size
    assert sum(gray.bytes_per_server) == size
    assert gray.total_time > clean.total_time
