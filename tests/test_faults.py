"""Chaos-harness matrix: fault-injecting mirrors, integrity recovery,
crash-resume, and the simulator-side fault mirrors.

Every end-to-end case asserts the full-file checksum — the point of the
robustness layer is that injected corruption, truncation, stalls, resets,
and crashes are *invisible* in the delivered bytes, only in the report's
accounting (re-fetch counts, retries, resumed bytes, served-byte totals).
All fault draws are seeded so the matrix is reproducible.
"""

import asyncio
import hashlib
import os
import threading
import time
import zlib

import numpy as np
import pytest

from repro.core.chunking import ChunkParams
from repro.transfer import (
    FaultPolicy,
    FleetModel,
    MDTPClient,
    RangeServer,
    Replica,
    ResumeJournal,
    Throttle,
    TransferIncompleteError,
    TransferReport,
    fetch_blob,
)
from repro.transfer.journal import merge_intervals, uncovered_intervals

MB = 1024 * 1024


def _sha(b) -> str:
    return hashlib.sha256(bytes(b)).hexdigest()


@pytest.fixture
def blob():
    rng = np.random.default_rng(0)
    return rng.integers(0, 256, size=4 * MB, dtype=np.uint8).tobytes()


def _mirror(blob, throttle=None, faults=None):
    s = RangeServer(throttle=throttle, faults=faults).start()
    s.add_blob("/data", blob)
    return s


# --------------------------------------------------------------------------
# Resume journal (unit)
# --------------------------------------------------------------------------


def test_interval_helpers():
    assert merge_intervals([(0, 4), (4, 4), (12, 2)]) == [(0, 8), (12, 2)]
    # overlap across crash generations unions cleanly
    assert merge_intervals([(0, 6), (4, 6), (20, 1)]) == [(0, 10), (20, 1)]
    assert merge_intervals([]) == []
    assert uncovered_intervals([(0, 8), (12, 2)], 20) == [(8, 4), (14, 6)]
    assert uncovered_intervals([], 5) == [(0, 5)]
    assert uncovered_intervals([(0, 5)], 5) == []


def test_journal_roundtrip_and_replay(tmp_path):
    p = str(tmp_path / "j.log")
    meta = {"step": 7}
    with ResumeJournal.open(p, 100, meta=meta) as jr:
        jr.record(0, 40, zlib.crc32(b"a" * 40))
        jr.record(60, 20, zlib.crc32(b"b" * 20))
    # same identity => records replay; uncovered is the complement
    jr2 = ResumeJournal.open(p, 100, meta=meta)
    assert jr2.covered() == [(0, 40), (60, 20)]
    assert uncovered_intervals(jr2.covered(), 100) == [(40, 20), (80, 20)]
    jr2.close()
    # foreign identity (different total) => fresh journal, nothing trusted
    jr3 = ResumeJournal.open(p, 200, meta=meta)
    assert jr3.covered() == []
    jr3.close()


def test_journal_truncates_torn_tail(tmp_path):
    p = str(tmp_path / "j.log")
    with ResumeJournal.open(p, 100) as jr:
        jr.record(0, 50, 123)
    with open(p, "a", encoding="ascii") as f:
        f.write("60 40 99")           # no newline: torn mid-append
    jr = ResumeJournal.open(p, 100)
    assert jr.covered() == [(0, 50)]  # torn record dropped
    jr.record(50, 25, 7)              # appends stay parseable after truncate
    jr.close()
    assert ResumeJournal.open(p, 100).covered() == [(0, 75)]


def test_journal_complete_deletes(tmp_path):
    p = str(tmp_path / "j.log")
    jr = ResumeJournal.open(p, 10)
    jr.record(0, 10)
    jr.complete()
    assert not os.path.exists(p)


# --------------------------------------------------------------------------
# Integrity: corruption / truncation / garbage / resets over real HTTP
# --------------------------------------------------------------------------


def test_corruption_refetched_from_alternate_mirror(blob):
    """A mirror that corrupts EVERY body must contribute nothing: each
    mismatched range is re-pooled banned-for-that-replica, re-fetched
    from the clean mirror, and the chronically corrupt mirror is retired
    once it crosses ``max_failures`` — yet the file arrives intact."""
    bad = _mirror(blob, faults=FaultPolicy(corrupt_rate=1.0, seed=3))
    good = _mirror(blob)
    try:
        replicas = [Replica("127.0.0.1", bad.port, "/data"),
                    Replica("127.0.0.1", good.port, "/data")]
        data, report = fetch_blob(
            replicas, len(blob),
            params=ChunkParams(initial_chunk=256 * 1024, large_chunk=MB),
            max_failures=3)
        assert _sha(data) == _sha(blob)
        bad_name = replicas[0].name
        assert report.corrupt_ranges[bad_name] >= 3
        assert bad_name in report.failed_replicas
        assert report.refetched_ranges >= 3
        assert bad.fault_counts["corrupt"] >= 3
        # none of the corrupt mirror's bytes were counted as delivered
        assert report.bytes_per_replica[replicas[1].name] == len(blob)
    finally:
        bad.stop()
        good.stop()


def test_truncated_bodies_recovered(blob):
    """Mid-body truncation (connection cut) on one mirror: the short
    range re-pools and the fleet still assembles the exact file."""
    flaky = _mirror(blob, faults=FaultPolicy(truncate_rate=0.5, seed=11))
    good = _mirror(blob)
    try:
        replicas = [Replica("127.0.0.1", flaky.port, "/data"),
                    Replica("127.0.0.1", good.port, "/data")]
        data, report = fetch_blob(
            replicas, len(blob),
            params=ChunkParams(initial_chunk=256 * 1024, large_chunk=MB),
            max_failures=50)
        assert _sha(data) == _sha(blob)
        assert flaky.fault_counts["truncate"] >= 1
        assert sum(report.bytes_per_replica.values()) == len(blob)
    finally:
        flaky.stop()
        good.stop()


def test_garbage_and_resets_recovered(blob):
    """Garbage responses and TCP resets trigger reconnect-with-backoff;
    the retry accounting surfaces on the report and the bytes survive."""
    flaky = _mirror(blob, faults=FaultPolicy(garbage_rate=0.25,
                                             reset_rate=0.25, seed=5))
    good = _mirror(blob)
    try:
        replicas = [Replica("127.0.0.1", flaky.port, "/data"),
                    Replica("127.0.0.1", good.port, "/data")]
        data, report = fetch_blob(
            replicas, len(blob),
            params=ChunkParams(initial_chunk=256 * 1024, large_chunk=MB),
            max_failures=50, retry_backoff_cap=0.2)
        assert _sha(data) == _sha(blob)
        # only kinds that fired have a key; which of the two fires first
        # depends on the load-dependent request sequence, so don't index
        counts = flaky.fault_counts
        assert counts.get("garbage", 0) + counts.get("reset", 0) >= 1
        assert report.retries_per_replica[replicas[0].name] >= 1
        assert sum(report.bytes_per_replica.values()) == len(blob)
    finally:
        flaky.stop()
        good.stop()


def test_stall_timeout_fails_over(blob):
    """A mirror that stalls forever must not stall the transfer: the
    per-request inactivity timeout converts the dead air into a retry,
    and the healthy mirror finishes well before the stall would."""
    stall = _mirror(blob, faults=FaultPolicy(stall_rate=1.0, stall_s=8.0,
                                             seed=2))
    good = _mirror(blob)
    try:
        replicas = [Replica("127.0.0.1", stall.port, "/data"),
                    Replica("127.0.0.1", good.port, "/data")]
        t0 = time.monotonic()
        data, report = fetch_blob(
            replicas, len(blob),
            params=ChunkParams(initial_chunk=256 * 1024, large_chunk=MB),
            max_failures=2, read_timeout=0.4, retry_backoff_cap=0.2)
        wall = time.monotonic() - t0
        assert _sha(data) == _sha(blob)
        assert wall < 6.0          # never served a full 8 s stall
        assert report.bytes_per_replica[replicas[1].name] == len(blob)
    finally:
        stall.stop()
        good.stop()


def test_kill_mid_pipeline_under_faults(blob):
    """Crash a mirror with pipelined ranges in flight while the survivor
    injects occasional truncations: every owed range re-pools exactly
    once (byte conservation) and the hash still matches."""
    big = blob * 2                      # slow enough that the kill lands
    victim = _mirror(big, throttle=Throttle(bytes_per_s=4 * MB,
                                            deterministic=True))
    survivor = _mirror(big, throttle=Throttle(bytes_per_s=30 * MB,
                                              deterministic=True),
                       faults=FaultPolicy(truncate_rate=0.4, seed=9))
    try:
        replicas = [Replica("127.0.0.1", victim.port, "/data"),
                    Replica("127.0.0.1", survivor.port, "/data")]

        def kill():
            victim.kill_connections()
            victim.stop()

        threading.Timer(0.1, kill).start()
        data, report = fetch_blob(
            replicas, len(big),
            params=ChunkParams(initial_chunk=256 * 1024, large_chunk=MB),
            max_failures=50, pipeline_depth=4, retry_backoff_cap=0.2)
        assert _sha(data) == _sha(big)
        # conservation: each byte delivered exactly once across the
        # kill re-pool AND the truncation re-pools
        assert sum(report.bytes_per_replica.values()) == len(big)
        assert survivor.fault_counts["truncate"] >= 1
        # the killed mirror cost at least one reconnect attempt
        assert report.retries_per_replica[replicas[0].name] >= 1
    finally:
        survivor.stop()
        try:
            victim.stop()
        except Exception:
            pass


def test_incomplete_transfer_raises_typed_error(blob):
    """With every replica retired for corruption, fetch must raise the
    dedicated error (not return a silently short buffer) carrying the
    delivered-byte accounting."""
    bad = _mirror(blob, faults=FaultPolicy(corrupt_rate=1.0, seed=1))
    try:
        replicas = [Replica("127.0.0.1", bad.port, "/data")]
        with pytest.raises(TransferIncompleteError) as ei:
            fetch_blob(replicas, len(blob),
                       params=ChunkParams(initial_chunk=256 * 1024,
                                          large_chunk=MB),
                       max_failures=2)
        err = ei.value
        assert err.expected_bytes == len(blob)
        assert err.done_bytes < len(blob)
        assert replicas[0].name in err.failed_replicas
        assert isinstance(err, IOError)   # compatibility contract
    finally:
        bad.stop()


# --------------------------------------------------------------------------
# Crash-resume (client + checkpoint restore), verified by served bytes
# --------------------------------------------------------------------------


def test_resume_after_cancel_is_byte_exact(blob, tmp_path):
    """Cancel a journaled fetch mid-transfer, then resume into the same
    buffer: the second fetch asks the mirrors only for uncovered bytes
    (served-byte accounting on the servers is the witness) and the
    assembled file is byte-exact."""
    servers = [_mirror(blob, throttle=Throttle(bytes_per_s=6 * MB,
                                               deterministic=True)),
               _mirror(blob, throttle=Throttle(bytes_per_s=6 * MB,
                                               deterministic=True))]
    jpath = str(tmp_path / "resume.log")
    try:
        replicas = [Replica("127.0.0.1", s.port, "/data") for s in servers]
        params = ChunkParams(initial_chunk=128 * 1024, large_chunk=256 * 1024)
        buf = bytearray(len(blob))

        async def first_leg():
            jr = ResumeJournal.open(jpath, len(blob),
                                    sync_interval_bytes=256 * 1024)
            client = MDTPClient(replicas, params=params)
            task = asyncio.ensure_future(
                client.fetch(len(blob), resume=jr, into=buf))
            try:
                while sum(s.served_bytes for s in servers) < len(blob) // 3:
                    await asyncio.sleep(0.01)
                    if task.done():      # finished before the threshold?
                        return await task
                task.cancel()
                with pytest.raises(asyncio.CancelledError):
                    await task
            finally:
                jr.close()
            return None

        asyncio.run(first_leg())
        served_first = sum(s.served_bytes for s in servers)
        jr = ResumeJournal.open(jpath, len(blob),
                                sync_interval_bytes=256 * 1024)
        resumed_ranges = jr.covered()
        assert resumed_ranges, "cancel landed before any journal record"

        async def second_leg():
            client = MDTPClient(replicas, params=params)
            try:
                return await client.fetch(len(blob), resume=jr, into=buf)
            finally:
                jr.close()

        _, report = asyncio.run(second_leg())
        assert _sha(buf) == _sha(blob)
        assert report.resumed_bytes > 0
        assert report.resumed_bytes == sum(n for _, n in resumed_ranges)
        # the mirrors only served what the journal did not cover (plus
        # bounded slack for ranges cut off mid-body by the cancel)
        served_second = sum(s.served_bytes for s in servers) - served_first
        assert served_second <= len(blob) - report.resumed_bytes + 512 * 1024
    finally:
        for s in servers:
            s.stop()


def test_restore_resume_fetches_only_missing(tmp_path):
    """Checkpoint restore with ``resume=``: a scratch dir pre-seeded with
    the first half of the blob (spool + journal) makes the mirrors serve
    only the missing tail."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.checkpoint import restore_checkpoint, save_checkpoint

    state = {"w": jax.random.normal(jax.random.PRNGKey(0), (512, 512)),
             "step": jnp.int32(3)}
    d = save_checkpoint(str(tmp_path / "ckpt"), 300, state)
    total = os.path.getsize(os.path.join(d, "data.bin"))
    with open(os.path.join(d, "data.bin"), "rb") as f:
        payload = f.read()

    scratch = tmp_path / "scratch"
    scratch.mkdir()
    half = total // 2
    with open(scratch / "data.spool", "wb") as f:
        f.write(payload[:half])
        f.truncate(total)
    jr = ResumeJournal.open(str(scratch / "journal.log"), total,
                            meta={"step": 300})
    jr.record(0, half, zlib.crc32(payload[:half]))
    jr.close()

    srv = RangeServer().start()
    base = "/ckpt/step_0000000300"
    srv.add_file(base + "/manifest.json", os.path.join(d, "manifest.json"))
    srv.add_file(base + "/data.bin", os.path.join(d, "data.bin"))
    try:
        restored, step = restore_checkpoint(
            str(tmp_path / "ckpt"), state, step=300,
            replicas=[Replica("127.0.0.1", srv.port, "/ckpt")],
            resume=str(scratch))
        assert step == 300
        assert bool(jnp.all(restored["w"] == state["w"]))
        # the blob fetch skipped the journaled half (manifest riding on
        # the same server is tiny next to the half-blob margin)
        assert srv.served_bytes < total - half // 2
        # a completed restore cleans up after itself
        assert not os.path.exists(scratch / "journal.log")
        assert not os.path.exists(scratch / "data.spool")
    finally:
        srv.stop()


# --------------------------------------------------------------------------
# Fleet health: chronic corruption deprioritizes a mirror
# --------------------------------------------------------------------------


def test_fleet_model_health_decays_and_recovers():
    fm = FleetModel()
    fm.register("t1")
    for name in ("a:1", "b:2"):
        fm.observe_chunk("t1", name, nbytes=8 * MB, elapsed=1.0,
                         rtt_included=False)
    reps = [Replica("a", 1, "/d"), Replica("b", 2, "/d")]
    clean = fm.allocation_view("t1", reps, [8.0 * MB, 8.0 * MB])
    for _ in range(4):
        fm.observe_corruption("a:1")
    tainted = fm.allocation_view("t1", reps, [8.0 * MB, 8.0 * MB])
    assert tainted[0] < clean[0] * 0.5        # 0.7**4 ≈ 0.24
    assert tainted[1] == pytest.approx(clean[1])
    assert fm.snapshot()["a:1"]["corruptions"] == 4
    # clean evidence rebuilds trust, but slowly (asymmetric on purpose)
    for _ in range(10):
        fm.observe_chunk("t1", "a:1", nbytes=8 * MB, elapsed=1.0,
                         rtt_included=False)
    healed = fm.allocation_view("t1", reps, [8.0 * MB, 8.0 * MB])
    assert tainted[0] < healed[0] < clean[0]


# --------------------------------------------------------------------------
# Simulator mirrors: ServerSpec loss/corruption + SimConfig fault rates
# --------------------------------------------------------------------------


def test_python_sim_fault_traces_complete_and_pay_overhead():
    from repro.core import MDTPPolicy, simulate
    from repro.core.scenarios import fault_traces, paper_baseline

    size = 256 * MB
    params = ChunkParams(initial_chunk=4 * MB, large_chunk=32 * MB)
    clean = simulate(MDTPPolicy(params, retry_after=0.25),
                     paper_baseline(jitter=0.0), size, seed=4)
    clean.check_integrity()
    for trace in fault_traces():
        r = simulate(MDTPPolicy(params, retry_after=0.25),
                     list(trace.servers), size, seed=4)
        r.check_integrity()                       # every byte exactly once
        assert sum(r.bytes_per_server) == size
        assert r.total_time >= clean.total_time * 0.999, trace.name


def test_python_sim_fault_free_taint_is_identity():
    from repro.core import MDTPPolicy, simulate
    from repro.core.scenarios import paper_baseline, with_faults

    size = 128 * MB
    base = paper_baseline()
    a = simulate(MDTPPolicy(), base, size, seed=6)
    b = simulate(MDTPPolicy(), with_faults(base), size, seed=6)
    assert a.total_time == b.total_time           # zero rates draw no RNG
    assert a.bytes_per_server == b.bytes_per_server


def test_jax_sim_faults_slower_yet_complete():
    pytest.importorskip("jax")
    from repro.core.jax_sim import SimConfig, simulate_transfer

    bw = [30.0 * MB, 60.0 * MB, 120.0 * MB]
    size = 512 * MB
    params = ChunkParams(initial_chunk=8 * MB, large_chunk=64 * MB)
    for engine in ("event", "round"):
        clean = simulate_transfer(bw, 0.02, size, params, seed=11,
                                  engine=engine)
        faulty = simulate_transfer(
            bw, 0.02, size, params, seed=11, engine=engine,
            config=SimConfig(loss_rate=0.05, corruption_rate=0.10))
        assert float(faulty.total_time) > float(clean.total_time), engine
        # failed chunks roll back off the cursor and re-issue: delivered
        # bytes still equal the file size
        np.testing.assert_allclose(
            float(np.sum(np.asarray(faulty.bytes_per_server))), size,
            rtol=1e-5)


def test_jax_round_and_scan_agree_under_faults():
    pytest.importorskip("jax")
    from repro.core.autotune import _sized_config
    from repro.core.jax_sim import SimConfig, simulate_transfer

    bw = [30.0 * MB, 60.0 * MB, 120.0 * MB]
    size = 512 * MB
    params = ChunkParams(initial_chunk=8 * MB, large_chunk=64 * MB,
                         mode="proportional")
    cfg = SimConfig(loss_rate=0.05, corruption_rate=0.10, exact_sizes=False)
    cfg = _sized_config(cfg, "scan",
                        [(params.initial_chunk, params.large_chunk)], size)
    r = simulate_transfer(bw, 0.02, size, params, seed=11, engine="round",
                          config=cfg)
    s = simulate_transfer(bw, 0.02, size, params, seed=11, engine="scan",
                          config=cfg)
    assert float(r.total_time) == float(s.total_time)
    np.testing.assert_allclose(np.asarray(r.bytes_per_server),
                               np.asarray(s.bytes_per_server), rtol=1e-6)


def test_jax_sim_fault_free_replay_bit_identical():
    """Zero fault rates must not consume extra PRNG splits: results are
    bit-identical to a build that predates the fault axes."""
    pytest.importorskip("jax")
    from repro.core.jax_sim import SimConfig, simulate_transfer

    bw = [30.0 * MB, 60.0 * MB, 120.0 * MB]
    size = 256 * MB
    params = ChunkParams(initial_chunk=8 * MB, large_chunk=64 * MB)
    jittery = SimConfig(jitter=0.3)
    tainted = SimConfig(jitter=0.3, loss_rate=0.0, corruption_rate=0.0)
    for engine in ("event", "round"):
        a = simulate_transfer(bw, 0.02, size, params, seed=13,
                              engine=engine, config=jittery)
        b = simulate_transfer(bw, 0.02, size, params, seed=13,
                              engine=engine, config=tainted)
        assert float(a.total_time) == float(b.total_time), engine


def test_autotune_prices_in_fault_tax():
    """The fused sweep under corruption must predict strictly slower
    transfers (re-fetch overhead) while staying finite — the signal the
    online tuners use to shrink L under chronic corruption."""
    pytest.importorskip("jax")
    from repro.core.autotune import autotune_chunk_params

    bw = [30.0 * MB, 60.0 * MB, 120.0 * MB]
    size = 1024 * MB
    clean = autotune_chunk_params(bw, rtt=0.03, file_size=size)
    faulty = autotune_chunk_params(bw, rtt=0.03, file_size=size,
                                   corruption_rate=0.15, n_seeds=4)
    assert np.isfinite(faulty.predicted_time)
    assert faulty.predicted_time > clean.predicted_time


def test_retune_folds_observed_corruption_rate():
    """A client whose last transfer saw checksum failures re-tunes with
    the measured corruption rate (and a seed sweep), matching a direct
    autotune call with the same effective rate."""
    pytest.importorskip("jax")
    from repro.core.autotune import autotune_chunk_params

    GB = 1024 * MB
    replicas = [Replica("h0", 1, "/b"), Replica("h1", 2, "/b")]
    client = MDTPClient(replicas)
    client.last_report = TransferReport(
        total_bytes=1, elapsed=1.0, bytes_per_replica={},
        requests_per_replica={"h0:1": 30, "h1:2": 10},
        failed_replicas=[], refetched_ranges=8,
        observed_throughputs={"h0:1": 50.0 * MB, "h1:2": 10.0 * MB},
        observed_rtts={"h0:1": 0.03, "h1:2": 0.03},
        corrupt_ranges={"h0:1": 8, "h1:2": 0})
    res = client.retune(2 * GB)
    expect = autotune_chunk_params(
        [50.0 * MB, 10.0 * MB], rtt=[0.03, 0.03], file_size=2 * GB,
        pipeline_depth=client.pipeline_depth,
        corruption_rate=8 / 40, n_seeds=4)
    assert res.predicted_times == expect.predicted_times
    assert res.params == expect.params
