"""Peer-assisted checkpoint broadcast: swarm conservation, origin
offload, and peer-death fallback over real loopback sockets.

Every swarm case asserts the full-blob checksum on EVERY restorer — the
point of the broadcast layer is that peers trading stripes is invisible
in the delivered bytes, only in the accounting (origin vs peer
served-byte totals).  Throttles are deterministic token buckets so the
cases are load-independent.
"""

import asyncio
import hashlib
import http.client
import threading

import numpy as np
import pytest

from repro.core.chunking import ChunkParams
from repro.transfer import (
    BufferSink,
    CallableSink,
    MDTPClient,
    PeerMirror,
    RangeServer,
    Replica,
    Sink,
    Throttle,
)

MB = 1024 * 1024

#: swarm-scale geometry: chunks small enough that no single origin grab
#: outlives the peers' ramp-up (the 4 MiB defaults would hand every
#: restorer half the blob before any mirror had bytes to trade).
PARAMS = ChunkParams(initial_chunk=128 * 1024, large_chunk=256 * 1024,
                     min_chunk=32 * 1024)


def _sha(b) -> str:
    return hashlib.sha256(bytes(b)).hexdigest()


@pytest.fixture
def blob():
    rng = np.random.default_rng(7)
    return rng.integers(0, 256, size=2 * MB, dtype=np.uint8).tobytes()


def _origin(blob, rate=8 * MB):
    s = RangeServer(throttle=Throttle(bytes_per_s=rate, shared=True,
                                      deterministic=True)).start()
    s.add_blob("/data", blob)
    return s


def _client(replicas):
    return MDTPClient(replicas, params=PARAMS, coverage_refresh_s=0.01)


def _run_swarm(blob, n, rate=8 * MB):
    """n restorers, one origin, full peer mesh.  Returns
    (sinks, origin_served, peer_served)."""
    origin = _origin(blob, rate)
    sinks = [BufferSink(len(blob)) for _ in range(n)]
    mirrors = [PeerMirror(s, throttle=Throttle(bytes_per_s=rate,
                                               shared=True,
                                               deterministic=True))
               for s in sinks]
    try:
        rep = Replica("127.0.0.1", origin.port, "/data")

        async def one(j):
            replicas = [rep] + [m.replica for k, m in enumerate(mirrors)
                                if k != j]
            await _client(replicas).fetch(len(blob), sink=sinks[j],
                                          stripe=(j, n))

        async def go():
            await asyncio.gather(*(one(j) for j in range(n)))

        asyncio.run(go())
        return sinks, origin.served_bytes, [m.served_bytes for m in mirrors]
    finally:
        origin.stop()
        for m in mirrors:
            m.stop()


# --------------------------------------------------------------------------
# Mirror advertisement (unit)
# --------------------------------------------------------------------------


def test_mirror_advertises_coverage_and_refuses_uncovered(blob):
    """A filling sink's mirror must advertise exactly what it holds
    (``X-Available-Ranges`` on HEAD), serve covered ranges byte-exact,
    and refuse uncovered ones with 416 — never invented bytes."""
    sink = BufferSink(len(blob))
    half = len(blob) // 2
    sink.writable(0, half)[:] = blob[:half]
    sink.commit(0, half)
    m = PeerMirror(sink)
    try:
        c = http.client.HTTPConnection("127.0.0.1", m.port)
        c.request("HEAD", "/data")
        r = c.getresponse()
        r.read()
        assert r.status == 200
        assert r.getheader("X-Available-Ranges") == f"0-{half - 1}"

        c.request("GET", "/data", headers={"Range": "bytes=0-65535"})
        r = c.getresponse()
        assert r.status == 206
        assert r.read() == blob[:65536]

        c.request("GET", "/data",
                  headers={"Range": f"bytes={half}-{half + 100}"})
        r = c.getresponse()
        r.read()
        assert r.status == 416
        c.close()
    finally:
        m.stop()


def test_sink_protocol_runtime_checks():
    assert isinstance(BufferSink(16), Sink)
    assert isinstance(CallableSink(lambda s, mv: None), Sink)
    assert not isinstance(object(), Sink)
    with pytest.raises(ValueError):
        PeerMirror(CallableSink(lambda s, mv: None), total=16)


# --------------------------------------------------------------------------
# Swarm end-to-end
# --------------------------------------------------------------------------


def test_swarm_conservation_byte_exact(blob):
    """Three restorers trading stripes all end byte-exact, and the
    trading actually happened: peers served a nonzero share."""
    sinks, origin_served, peer_served = _run_swarm(blob, 3)
    want = _sha(blob)
    for s in sinks:
        assert _sha(s) == want
    assert sum(peer_served) > 0, "no peer ever served a byte"
    # whatever arrived came off a real wire exactly once per restorer
    assert origin_served + sum(peer_served) >= 3 * len(blob)
    for s in sinks:
        assert s.duplicate_bytes == 0


def test_origin_egress_sublinear(blob):
    """At N=4 the origin must send each byte ~once, not once per
    restorer: egress stays under 2x the blob where independent clients
    would pay 4x."""
    sinks, origin_served, peer_served = _run_swarm(blob, 4)
    want = _sha(blob)
    for s in sinks:
        assert _sha(s) == want
    assert origin_served <= 2 * len(blob), \
        f"origin served {origin_served / len(blob):.2f}x the blob"
    assert sum(peer_served) >= 2 * len(blob)


def test_peer_death_mid_serve_falls_back_to_origin(blob):
    """Kill a peer mirror while the restorer is drawing from it: its
    advertised coverage must drop out of the union and every span it
    owed must re-open to the origin — transfer completes byte-exact."""
    origin = _origin(blob, rate=4 * MB)
    donor = BufferSink(len(blob))
    half = len(blob) // 2
    donor.writable(0, half)[:] = blob[:half]
    donor.commit(0, half)
    m = PeerMirror(donor, throttle=Throttle(bytes_per_s=8 * MB,
                                            shared=True,
                                            deterministic=True))
    try:
        replicas = [Replica("127.0.0.1", origin.port, "/data"), m.replica]
        client = MDTPClient(replicas, params=PARAMS,
                            coverage_refresh_s=0.01, max_failures=2)

        def kill():
            m.server.kill_connections()
            m.stop()

        killer = threading.Timer(0.15, kill)
        killer.start()
        data, report = asyncio.run(client.fetch(len(blob)))
        killer.cancel()
        assert _sha(data) == _sha(blob)
        # the origin finished the job, including the dead donor's half
        assert origin.served_bytes > half
    finally:
        origin.stop()
        try:
            m.stop()
        except Exception:
            pass
