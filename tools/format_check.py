"""Offline-verifiable formatting gate (subset of ``ruff format``).

The CI lint job wants ``ruff format --check`` to gate the build, but the
development container has no ruff binary and no network, so a
tool-generated repo-wide reformat cannot be produced (or verified)
locally — only ruff itself emits ruff-stable output.  This script
enforces the subset of the formatter's invariants that IS deterministic
without the tool, so the tree stays normalized and the eventual
``ruff format`` adoption diff is purely structural.

Rules, per file kind (like ruff format, nothing inside a string literal
is ever touched — Python sources are tokenized and every line spanned by
a multi-line string is left verbatim):

* ``.py`` — no trailing whitespace, LF endings, no tabs in indentation,
  exactly one newline at EOF; all except the EOF rule skip lines inside
  multi-line string literals (and files that fail to tokenize are left
  alone entirely).
* ``.json`` — same rules (JSON strings cannot span lines or contain raw
  tabs, so whole-line normalization is value-preserving).
* ``.md`` / ``.txt`` / ``.yml`` / ``.yaml`` / ``.toml`` — EOF-newline
  normalization only: Markdown trailing spaces are hard line breaks,
  YAML block scalars and TOML multi-line strings preserve interior
  whitespace, so in-line edits are not safe there.

Usage::

    python tools/format_check.py          # check, exit 1 on violations
    python tools/format_check.py --fix    # rewrite files in place
"""

from __future__ import annotations

import argparse
import io
import os
import sys
import tokenize

#: directories never scanned (VCS internals, caches, artifacts).
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".ruff_cache",
             "node_modules", ".hypothesis"}
#: suffixes getting full line normalization.
FULL_SUFFIXES = (".py", ".json")
#: suffixes getting EOF-newline normalization only.
EOF_ONLY_SUFFIXES = (".md", ".txt", ".yml", ".yaml", ".toml")

def _protected_lines(text: str) -> set | None:
    """1-based numbers of every line spanned by a multi-line string
    token — those lines hold literal VALUE and must stay verbatim.
    Returns None when the file does not tokenize (leave it untouched)."""
    protected = set()
    fstring_starts = []          # 3.12+: f-strings arrive in three tokens
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            name = tokenize.tok_name[tok.type]
            if name == "FSTRING_START":
                fstring_starts.append(tok.start[0])
            elif name == "FSTRING_END" and fstring_starts:
                start = fstring_starts.pop()
                if tok.end[0] > start:   # only multi-line f-strings
                    protected.update(range(start, tok.end[0] + 1))
            elif (tok.type == tokenize.STRING
                    and tok.end[0] > tok.start[0]):
                protected.update(range(tok.start[0], tok.end[0] + 1))
    except (tokenize.TokenError, IndentationError, SyntaxError,
            ValueError):
        return None
    return protected


def _normalize_line(line: str) -> str:
    line = line[:-1] if line.endswith("\r") else line
    line = line.rstrip()
    indent_len = len(line) - len(line.lstrip())
    return line[:indent_len].replace("\t", "    ") + line[indent_len:]


def _trim_eof(lines: list) -> list:
    while lines and lines[-1] == "":
        lines.pop()
    return lines


def normalize(text: str, kind: str = ".py") -> str:
    """Normalized content for one file (``kind`` = file suffix)."""
    if not text:
        return ""
    if kind in EOF_ONLY_SUFFIXES:
        body = text[:-1] if text.endswith("\n") else text
        while body.endswith("\n"):
            body = body[:-1]
        return body + "\n" if body else ""
    protected = _protected_lines(text) if kind == ".py" else set()
    if protected is None:
        return text                      # not tokenizable: hands off
    lines = text.split("\n")
    out = [line if (i + 1) in protected else _normalize_line(line)
           for i, line in enumerate(lines)]
    # exactly one newline at EOF — safe even for .py: a file cannot END
    # inside a string literal (that would not tokenize), and a
    # terminated literal's last line carries its closing quotes, so the
    # trailing empties trimmed here are always outside every literal
    out = _trim_eof(out)
    return "\n".join(out) + "\n" if out else ""


def iter_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d not in SKIP_DIRS)
        for name in sorted(filenames):
            if name.endswith(FULL_SUFFIXES + EOF_ONLY_SUFFIXES):
                yield os.path.join(dirpath, name)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fix", action="store_true",
                    help="rewrite violating files in place")
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."))
    args = ap.parse_args(argv)
    root = os.path.abspath(args.root)

    dirty = []
    for path in iter_files(root):
        with open(path, encoding="utf-8") as f:
            original = f.read()
        fixed = normalize(original, os.path.splitext(path)[1])
        if fixed != original:
            dirty.append(os.path.relpath(path, root))
            if args.fix:
                with open(path, "w", encoding="utf-8", newline="\n") as f:
                    f.write(fixed)
    if dirty:
        verb = "reformatted" if args.fix else "needs formatting"
        for p in dirty:
            print(f"{verb}: {p}")
        print(f"{len(dirty)} file(s) {verb}")
        return 0 if args.fix else 1
    print("format check clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
