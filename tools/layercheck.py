"""Layering gate: the sans-I/O scheduling core stays sans-I/O.

``repro.transfer.sched`` exists so the MDTP allocator's decision code
can be driven by the real socket client, the fleet manager, simulators,
and bare unit tests alike.  That only holds while the package (and
everything it imports, transitively, inside ``repro``) touches neither
the event loop, nor sockets, nor JAX — one stray convenience import
silently couples every consumer to the transport/accelerator stack and
breaks import-without-JAX deployments.

This script walks the import graph statically (AST — nothing is
executed, so a violation cannot hide behind an import-time side effect):
starting from every module of each *root* package, it resolves
``import`` / ``from ... import`` statements, follows edges into modules
under ``src/``, and reports any reachable import of a *forbidden*
module.  Conditional imports count — an import inside ``if TYPE_CHECKING:``
or a function body is still a coupling the gate exists to forbid (the
one exception: ``from __future__`` is ignored, and stdlib/third-party
modules other than the forbidden list are allowed — "pure" here means
no I/O/JAX, not no stdlib).

Usage::

    python tools/layercheck.py            # exit 1 on violations

Checked contracts (``CONTRACTS``): each maps a root package to the
module prefixes it must never reach.  Add a row when a new layer makes
a purity promise.
"""

from __future__ import annotations

import ast
import os
import sys

#: root package -> forbidden module prefixes (matched against the full
#: dotted name of every import reachable from the root).
CONTRACTS = {
    "repro.transfer.sched": (
        "asyncio", "socket", "selectors", "ssl",
        "jax", "jaxlib",
        "repro.core.jax_alloc", "repro.core.jax_sim",
        "repro.core.autotune", "repro.core.online",
        "repro.transfer.client", "repro.transfer.server",
        "repro.transfer.manager", "repro.transfer.transport",
    ),
}

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")


def _module_path(name: str, src: str) -> str | None:
    """Filesystem path of dotted module ``name`` under ``src`` (package
    ``__init__.py`` or plain module), None when it is not ours."""
    parts = name.split(".")
    pkg = os.path.join(src, *parts)
    if os.path.isfile(os.path.join(pkg, "__init__.py")):
        return os.path.join(pkg, "__init__.py")
    mod = pkg + ".py"
    if os.path.isfile(mod):
        return mod
    return None


def _package_modules(root: str, src: str) -> list[str]:
    """Every module of dotted package ``root`` (recursively), by walking
    the tree — the gate must see modules nobody imports yet."""
    path = os.path.join(src, *root.split("."))
    if os.path.isfile(path + ".py"):
        return [root]
    out = []
    for dirpath, dirnames, filenames in os.walk(path):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        rel = os.path.relpath(dirpath, src).replace(os.sep, ".")
        for name in sorted(filenames):
            if not name.endswith(".py"):
                continue
            out.append(rel if name == "__init__.py"
                       else f"{rel}.{name[:-3]}")
    return out


def _imports_of(path: str, module: str) -> list[tuple[str, int]]:
    """``(dotted_name, lineno)`` for every import statement in the file.

    Relative imports resolve against ``module`` (the file's own dotted
    name); ``from pkg import name`` yields both ``pkg`` and
    ``pkg.name`` so a submodule pulled in via ``from`` is followed.
    """
    with open(path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=path)
    pkg_parts = module.split(".")
    if os.path.basename(path) != "__init__.py":
        pkg_parts = pkg_parts[:-1]          # containing package
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.append((alias.name, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            if node.level:
                base = pkg_parts[:len(pkg_parts) - (node.level - 1)]
                stem = ".".join(base + ([node.module] if node.module else []))
            else:
                stem = node.module or ""
            if stem:
                out.append((stem, node.lineno))
            for alias in node.names:
                if alias.name != "*" and stem:
                    out.append((f"{stem}.{alias.name}", node.lineno))
    return out


def _forbidden(name: str, prefixes: tuple[str, ...]) -> bool:
    return any(name == p or name.startswith(p + ".") for p in prefixes)


def check_contract(root: str, prefixes: tuple[str, ...],
                   src: str = _SRC) -> list[str]:
    """Violation strings for one contract (empty = clean)."""
    src = os.path.abspath(src)
    seen: set[str] = set()
    queue = _package_modules(root, src)
    if not queue:
        return [f"{root}: package not found under {src}"]
    violations = []
    while queue:
        mod = queue.pop()
        if mod in seen:
            continue
        seen.add(mod)
        path = _module_path(mod, src)
        if path is None:
            continue                        # stdlib/third-party: not walked
        flagged: set[tuple[str, int]] = set()
        for name, lineno in _imports_of(path, mod):
            if _forbidden(name, prefixes):
                # one finding per import statement: ``from jax import
                # numpy`` yields jax AND jax.numpy — report the first
                if (path, lineno) not in flagged:
                    flagged.add((path, lineno))
                    violations.append(
                        f"{os.path.relpath(path, src)}:{lineno}: {root} "
                        f"must not reach {name}")
                continue
            # follow edges into our own tree (prefix chain: ``import
            # a.b.c`` loads a and a.b too)
            parts = name.split(".")
            for i in range(1, len(parts) + 1):
                prefix = ".".join(parts[:i])
                if prefix not in seen and _module_path(prefix, src):
                    queue.append(prefix)
    return sorted(set(violations))


def main(argv=None) -> int:
    violations = []
    for root, prefixes in CONTRACTS.items():
        violations += check_contract(root, prefixes)
    if violations:
        for v in violations:
            print(v)
        print(f"{len(violations)} layering violation(s)")
        return 1
    print("layer check clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
